"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis-generated shapes."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _data(n, k, dtype):
    X = RNG.normal(size=(n, k)).astype(dtype)
    w = RNG.uniform(0.1, 2.0, size=(n,)).astype(np.float32)
    y = RNG.choice([-1.0, 1.0], size=(n,)).astype(np.float32)
    wv = RNG.normal(size=(k,)).astype(np.float32)
    return X, w, y, wv


@pytest.mark.parametrize("n,k", [(64, 32), (100, 37), (512, 256),
                                 (1000, 130), (9, 513)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_weighted_gram_matches_ref(n, k, dtype):
    X, w, _, _ = _data(n, k, np.float32)
    X = jnp.asarray(X, dtype)
    got = ops.weighted_gram(X, jnp.asarray(w), backend="interpret",
                            block_n=128, block_k=128)
    want = ref.weighted_gram(X, jnp.asarray(w))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("n,k", [(64, 32), (257, 100), (512, 256)])
def test_fused_estep_matches_ref(n, k):
    X, _, y, wv = _data(n, k, np.float32)
    m_p, g_p, b_p = ops.fused_estep(jnp.asarray(X), jnp.asarray(y),
                                    jnp.asarray(y), jnp.asarray(wv),
                                    eps=1e-6, backend="interpret",
                                    block_n=128)
    m_r, g_r, b_r = ref.fused_estep(jnp.asarray(X), jnp.asarray(y),
                                    jnp.asarray(y), jnp.asarray(wv), 1e-6)
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_r), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_r), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(b_p), np.asarray(b_r), rtol=2e-3,
                               atol=2e-3 * max(1.0, np.abs(b_r).max()))


@pytest.mark.parametrize("n,k", [(64, 32), (100, 37), (512, 256),
                                 (1000, 130), (9, 513), (300, 600)])
def test_syrk_tri_matches_ref(n, k):
    """Triangle-blocked SYRK == dense oracle on non-block-aligned shapes
    (exercises the flattened-triangular-index block maps + the mirror)."""
    X, w, _, _ = _data(n, k, np.float32)
    got = ops.syrk_tri(jnp.asarray(X), jnp.asarray(w), backend="interpret",
                       block_n=128, block_k=128)
    want = ref.weighted_gram(jnp.asarray(X), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3 * np.abs(want).max())
    # off-diagonal blocks are mirrored (bit-exact); within diagonal
    # blocks (w*a)*b vs (w*b)*a rounding leaves fp32-epsilon asymmetry
    # (posterior_params symmetrizes before factorizing).
    S = np.asarray(got)
    np.testing.assert_allclose(S, S.T, rtol=1e-5,
                               atol=1e-5 * max(1.0, np.abs(S).max()))


def test_tri_ij_enumerates_lower_triangle():
    """The integer-arithmetic flattened-index mapping must agree with
    np.tril_indices (the lookup-table generator) for large grids."""
    from repro.kernels.syrk import _tri, tri_ij
    nb = 100
    i, j = tri_ij(jnp.arange(_tri(nb), dtype=jnp.int32))
    ii, jj = np.tril_indices(nb)
    np.testing.assert_array_equal(np.asarray(i), ii)
    np.testing.assert_array_equal(np.asarray(j), jj)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 200), st.integers(1, 70), st.integers(0, 2 ** 20))
def test_syrk_tri_hypothesis_shapes(n, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.uniform(0.01, 5.0, size=(n,)).astype(np.float32)
    got = ops.syrk_tri(jnp.asarray(X), jnp.asarray(w),
                       backend="interpret", block_n=64, block_k=128)
    want = (X * w[:, None]).T @ X
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3,
                               atol=1e-3 * max(1.0, np.abs(want).max()))


@pytest.mark.parametrize("n,k", [(64, 32), (257, 100), (300, 600)])
@pytest.mark.parametrize("masked", [False, True])
def test_fused_stats_matches_ref(n, k, masked):
    """One-sweep (margin, gamma, b, S) == split oracle, odd shapes."""
    X, _, y, wv = _data(n, k, np.float32)
    wm = (jnp.asarray((RNG.uniform(size=n) > 0.2).astype(np.float32))
          if masked else None)
    got = ops.fused_stats(jnp.asarray(X), jnp.asarray(y), jnp.asarray(y),
                          jnp.asarray(wv), wm, eps=1e-6,
                          backend="interpret", block_n=128)
    want = ref.fused_stats(jnp.asarray(X), jnp.asarray(y), jnp.asarray(y),
                           jnp.asarray(wv), wm, 1e-6)
    for g, w_, name in zip(got, want, ("margin", "gamma", "b", "S")):
        g, w_ = np.asarray(g), np.asarray(w_)
        np.testing.assert_allclose(
            g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
            err_msg=name)


def test_fused_stats_large_k_falls_back_to_split():
    """K beyond the VMEM budget must route to the tiled split pair
    (never attempt the single-pass kernel) and still match the oracle."""
    n, k = 32, ops.FUSED_STATS_MAX_K + 128
    X, _, y, _ = _data(n, 8, np.float32)
    Xw = jnp.asarray(RNG.normal(size=(n, k)).astype(np.float32))
    wv = jnp.asarray(RNG.normal(size=k).astype(np.float32))
    got = ops.fused_stats(Xw, jnp.asarray(y), jnp.asarray(y), wv,
                          eps=1e-6, backend="interpret", block_n=32)
    want = ref.fused_stats(Xw, jnp.asarray(y), jnp.asarray(y), wv,
                           None, 1e-6)
    for g, w_, name in zip(got, want, ("margin", "gamma", "b", "S")):
        g, w_ = np.asarray(g), np.asarray(w_)
        np.testing.assert_allclose(
            g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
            err_msg=name)


def test_fused_stats_padded_rows_contribute_nothing():
    """Zero rows with rho=beta=0 must be exact no-ops for b and S."""
    X, _, y, wv = _data(96, 24, np.float32)
    Xp = np.concatenate([X, np.zeros((32, 24), np.float32)])
    yp = np.concatenate([y, np.zeros(32, np.float32)])
    a = ops.fused_stats(jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(yp),
                        jnp.asarray(wv), eps=1e-6, backend="interpret",
                        block_n=64)
    b = ref.fused_stats(jnp.asarray(X), jnp.asarray(y), jnp.asarray(y),
                        jnp.asarray(wv), None, 1e-6)
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["ref", "interpret"] + (
    ["pallas"] if __import__("jax").default_backend() == "tpu" else []))
@pytest.mark.parametrize("mode", ["EM", "MC"])
@pytest.mark.parametrize("n_valid", [1, 77, 128])
def test_accumulate_stats_partial_final_chunk_parity(backend, mode,
                                                     n_valid):
    """The streaming driver's padding path: a partially-valid final
    chunk must contribute exactly the stats of its valid rows, on every
    kernel backend (the padded-row no-op is a *layout* convention — zero
    X-rows and targets — and each backend must preserve it bit-exactly,
    the easy-to-miss hole being a kernel that touches gamma=eps padding
    rows through a non-zeroed term)."""
    import jax
    from repro.core.linear import accumulate_stats

    n_chunk, k = 128, 24
    rng = np.random.default_rng(n_valid)
    Xc = np.zeros((n_chunk, k), np.float32)
    yc = np.zeros((n_chunk,), np.float32)
    Xc[:n_valid] = rng.normal(size=(n_valid, k)).astype(np.float32)
    yc[:n_valid] = rng.choice([-1.0, 1.0], n_valid)
    wv = rng.normal(size=k).astype(np.float32)
    key = jax.random.PRNGKey(3)

    _, _, S_pad, b_pad = accumulate_stats(
        jnp.asarray(Xc), jnp.asarray(yc), jnp.asarray(yc),
        jnp.asarray(wv), mode=mode, key=key, eps=1e-6, backend=backend,
        row0=0)
    # oracle: valid rows only, ref backend (rowwise MC keys make the
    # draw independent of the chunk's padded tail)
    _, _, S_ref, b_ref = accumulate_stats(
        jnp.asarray(Xc[:n_valid]), jnp.asarray(yc[:n_valid]),
        jnp.asarray(yc[:n_valid]), jnp.asarray(wv), mode=mode, key=key,
        eps=1e-6, backend="ref", row0=0)
    S_pad, b_pad = np.asarray(S_pad), np.asarray(b_pad)
    S_ref, b_ref = np.asarray(S_ref), np.asarray(b_ref)
    np.testing.assert_allclose(
        S_pad, S_ref, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(S_ref).max()))
    np.testing.assert_allclose(
        b_pad, b_ref, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(b_ref).max()))


# ------------------------------------------------- fused Nystrom kernels
def _nystrom_problem(n, d, m, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    landmarks = X[rng.choice(n, size=min(m, n), replace=False)]
    if m > n:  # oversize-m cases: tile rows
        landmarks = rng.normal(size=(m, d)).astype(np.float32)
    proj = (0.2 * rng.normal(size=(m, m))).astype(np.float32)
    mask = (rng.uniform(size=n) > 0.25).astype(np.float32)
    y = rng.choice([-1.0, 1.0], n).astype(np.float32) * mask
    return X, landmarks, proj, mask, y


@pytest.mark.parametrize("n,d,m", [(64, 16, 32), (100, 7, 37),
                                   (257, 33, 65), (9, 130, 5)])
@pytest.mark.parametrize("add_bias", [False, True])
def test_nystrom_phi_matches_ref(n, d, m, add_bias):
    """Fused featurizer == host oracle on odd (N, D, m) with masked
    padded rows and the mask-valued bias column."""
    X, L, proj, mask, _ = _nystrom_problem(n, d, m)
    kw = dict(sigma=1.3, kind="rbf", add_bias=add_bias)
    got = ops.nystrom_phi(jnp.asarray(X), jnp.asarray(L),
                          jnp.asarray(proj), jnp.asarray(mask),
                          backend="interpret", block_n=64, **kw)
    want = ref.nystrom_phi(jnp.asarray(X), jnp.asarray(L),
                           jnp.asarray(proj), jnp.asarray(mask),
                           1.3, "rbf", add_bias)
    got, want = np.asarray(got), np.asarray(want)
    assert got.shape == (n, m + int(add_bias))
    np.testing.assert_allclose(got, want, rtol=2e-3,
                               atol=2e-3 * max(1.0, np.abs(want).max()))
    # masked rows must be EXACTLY zero — a zero X row is not a zero phi
    # row under rbf, so the kernel's explicit masking is load-bearing
    assert not np.any(got[mask == 0])


@pytest.mark.parametrize("n,d,m", [(64, 16, 32), (100, 7, 37),
                                   (257, 33, 65)])
@pytest.mark.parametrize("kind", ["rbf", "linear"])
def test_nystrom_fused_stats_matches_ref(n, d, m, kind):
    """One-pass featurize-and-accumulate == featurize-then-accumulate
    oracle: all four outputs, odd shapes, masked rows, phi-space bias."""
    X, L, proj, mask, y = _nystrom_problem(n, d, m, seed=m)
    wv = np.random.default_rng(1).normal(size=m + 1).astype(np.float32)
    kw = dict(sigma=0.9, kind=kind, add_bias=True)
    got = ops.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(wv), jnp.asarray(mask), eps=1e-6,
        backend="interpret", block_n=64, **kw)
    want = ref.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(wv), jnp.asarray(mask), 0.9, kind,
        True, 1e-6)
    for g, w_, name in zip(got, want, ("margin", "gamma", "b", "S")):
        g, w_ = np.asarray(g), np.asarray(w_)
        np.testing.assert_allclose(
            g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
            err_msg=name)


def test_nystrom_fused_masked_rows_contribute_nothing():
    """A block whose tail is masked must yield the stats of its valid
    rows only — the streaming driver's padded-tail path."""
    n, d, m, n_valid = 96, 12, 24, 61
    X, L, proj, _, _ = _nystrom_problem(n, d, m, seed=3)
    rng = np.random.default_rng(4)
    y = np.zeros(n, np.float32)
    y[:n_valid] = rng.choice([-1.0, 1.0], n_valid)
    mask = (np.arange(n) < n_valid).astype(np.float32)
    wv = rng.normal(size=m + 1).astype(np.float32)
    kw = dict(sigma=1.1, kind="rbf", add_bias=True, eps=1e-6)
    a = ops.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(wv), jnp.asarray(mask),
        backend="interpret", block_n=32, **kw)
    b = ops.nystrom_fused_stats(
        jnp.asarray(X[:n_valid]), jnp.asarray(L), jnp.asarray(proj),
        jnp.asarray(y[:n_valid]), jnp.asarray(y[:n_valid]),
        jnp.asarray(wv), jnp.asarray(np.ones(n_valid, np.float32)),
        backend="ref", **kw)
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]),
                               rtol=1e-3,
                               atol=1e-3 * np.abs(np.asarray(b[3])).max())


def test_nystrom_fused_oversize_m_falls_back():
    """Past the VMEM budget the dispatch must route to
    featurize-then-accumulate (never attempt the one-pass kernel) and
    still match the oracle."""
    n, d, m = 48, 6, ops.NYSTROM_FUSED_MAX_M + 8
    assert not ops.nystrom_fused_fits(m, d)
    X, L, proj, mask, y = _nystrom_problem(n, d, m, seed=5)
    wv = np.random.default_rng(2).normal(size=m).astype(np.float32)
    got = ops.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(wv), jnp.asarray(mask),
        sigma=1.0, kind="rbf", add_bias=False, eps=1e-6,
        backend="interpret")
    want = ref.nystrom_fused_stats(
        jnp.asarray(X), jnp.asarray(L), jnp.asarray(proj), jnp.asarray(y),
        jnp.asarray(y), jnp.asarray(wv), jnp.asarray(mask), 1.0, "rbf",
        False, 1e-6)
    for g, w_, name in zip(got, want, ("margin", "gamma", "b", "S")):
        g, w_ = np.asarray(g), np.asarray(w_)
        np.testing.assert_allclose(
            g, w_, rtol=2e-3, atol=2e-3 * max(1.0, np.abs(w_).max()),
            err_msg=name)


def test_nystrom_fused_fits_accounting():
    """The byte-budget check: paper-regime shapes fit; the landmark cap
    and a pathologically wide D do not."""
    assert ops.nystrom_fused_fits(256, 784)
    assert ops.nystrom_fused_fits(1024, 256)
    assert not ops.nystrom_fused_fits(ops.NYSTROM_FUSED_MAX_M + 1, 16)
    assert not ops.nystrom_fused_fits(1024, 8192)


@pytest.mark.parametrize("n1,n2,k,sigma", [(64, 64, 16, 1.0),
                                           (100, 37, 8, 0.5),
                                           (129, 257, 33, 2.0)])
def test_rbf_gram_matches_ref(n1, n2, k, sigma):
    X1 = RNG.normal(size=(n1, k)).astype(np.float32)
    X2 = RNG.normal(size=(n2, k)).astype(np.float32)
    got = ops.rbf_gram(jnp.asarray(X1), jnp.asarray(X2), sigma=sigma,
                       backend="interpret", block_n=64)
    want = ref.rbf_gram(jnp.asarray(X1), jnp.asarray(X2), sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_rbf_gram_diagonal_is_one():
    X = RNG.normal(size=(50, 7)).astype(np.float32)
    G = np.asarray(ops.rbf_gram(jnp.asarray(X), jnp.asarray(X), sigma=1.3,
                                backend="interpret", block_n=64))
    np.testing.assert_allclose(np.diag(G), 1.0, atol=1e-5)
    np.testing.assert_allclose(G, G.T, atol=1e-5)
    assert G.max() <= 1.0 + 1e-5


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 200), st.integers(1, 70), st.integers(0, 2 ** 20))
def test_weighted_gram_hypothesis_shapes(n, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.uniform(0.01, 5.0, size=(n,)).astype(np.float32)
    got = ops.weighted_gram(jnp.asarray(X), jnp.asarray(w),
                            backend="interpret", block_n=64, block_k=128)
    want = (X * w[:, None]).T @ X
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3,
                               atol=1e-3 * max(1.0, np.abs(want).max()))


def test_weighted_gram_psd_property():
    """S = X^T diag(w) X with w > 0 must be PSD (solver precondition)."""
    X, w, _, _ = _data(300, 40, np.float32)
    S = np.asarray(ops.weighted_gram(jnp.asarray(X), jnp.asarray(w),
                                     backend="interpret"))
    eig = np.linalg.eigvalsh(S.astype(np.float64))
    assert eig.min() > -1e-3 * max(1.0, eig.max())
