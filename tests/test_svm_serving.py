"""Serving-path suite: served-score parity (bitwise on the dispatch
path), no-retrace regression, MC uncertainty vs the host Sigma oracle,
the score-convention fix, pad_features_to's width guard, the _phi /
device-path feature-order pin, weight paging and the serve loop."""
import time

import numpy as np
import pytest

from repro.core import PEMSVM, SVMConfig
from repro.core.nystrom import NystromSVM
from repro.data.pipeline import pad_features_to
from repro.serving import (DeadlineExceeded, ServableModel,  # noqa: F401
                           ServeLoop, ServeRejected, SVMScorer,
                           WeightPager, phi_never_materialized)
from repro.serving.svm_serve import TRACE_COUNTS


def _problem(task, n=420, d=11, m=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    w = rng.normal(size=d)
    if task == "SVR":
        y = (X @ w + 0.05 * rng.normal(size=n)).astype(np.float32)
    elif task == "MLT":
        y = np.argmax(X @ rng.normal(size=(m, d)).T, 1).astype(np.int32)
    else:
        y = np.where(X @ w > 0, 1.0, -1.0).astype(np.float32)
    return X, y


def _fit(task, family, **cfg_kw):
    X, y = _problem(task)
    if family == "linear":
        svm = PEMSVM(SVMConfig(task=task, num_classes=3, max_iters=25,
                               **cfg_kw))
        svm.fit(X, y)
        return svm, X
    ny = NystromSVM(SVMConfig(formulation="KRN", task=task,
                              num_classes=3, sigma=3.0, lam=0.1,
                              max_iters=25, **cfg_kw), n_landmarks=24)
    ny.fit(X, y)
    return ny, X


# --------------------------------------------------------------- parity
@pytest.mark.parametrize("task", ["CLS", "SVR", "MLT"])
@pytest.mark.parametrize("family", ["linear", "nystrom"])
def test_served_scores_bitwise_vs_oracle(task, family):
    """Bucketed/padded/batched serving == decision_function, BITWISE:
    the fixed-tile score cell makes a request's bits independent of
    which bucket it rides and what shares the batch (incl. the ragged
    final bucket)."""
    model, X = _fit(task, family)
    oracle = model.decision_function(X)  # one big dispatch

    pager = WeightPager()
    pager.register(model.export_servable(name="m"))
    loop = ServeLoop(pager)
    # Ragged request mix: coalesced into one batch of 420 rows ->
    # bucket 512 with a 92-row masked tail; the oracle above ran at
    # bucket 512 too, but the single-row and 77-row dispatches below
    # run at bucket 128.
    sizes = [1, 77, 130, 212]
    futs, i = [], 0
    for s in sizes:
        futs.append(loop.submit("m", X[i:i + s]))
        i += s
    assert loop.step() == len(sizes)
    served = np.concatenate([f.result(timeout=5) for f in futs])
    flat = served[:, 0] if task != "MLT" else served[:, :3]
    assert np.array_equal(flat, oracle)

    # Singleton dispatches (smallest bucket) match the same oracle bits.
    one = np.concatenate(
        [loop.pager.scorer("m").score(X[j:j + 1]) for j in (0, 133, 419)])
    picks = oracle[[0, 133, 419]]
    got = one[:, 0] if task != "MLT" else one[:, :3]
    assert np.array_equal(got, picks)


def test_exact_krn_serves_through_fused_cell():
    """The exact-Gram model rides the same Nystrom score cell
    (landmarks = train rows, proj = omega column, W = [[1.]])."""
    rng = np.random.default_rng(1)
    r_ = np.concatenate([rng.uniform(0, 1, 100), rng.uniform(1.5, 2.5, 100)])
    th = rng.uniform(0, 2 * np.pi, 200)
    X = np.stack([r_ * np.cos(th), r_ * np.sin(th)], 1).astype(np.float32)
    y = np.concatenate([np.ones(100), -np.ones(100)]).astype(np.float32)
    k = PEMSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7,
                         max_iters=25))
    k.fit(X, y)
    m = k.export_servable()
    assert m.family == "nystrom" and m.weights.shape == (1, 1)
    assert np.array_equal(SVMScorer(m).margins(X),
                          k.decision_function(X))
    assert k.score(X, y) > 0.95
    # and the margins agree with the direct Gram-matvec oracle
    from repro.core import kernel as krn
    import jax.numpy as jnp
    f = np.asarray(krn.decision_function(
        jnp.asarray(k._weights[:200]), jnp.asarray(k._train_X),
        jnp.asarray(X), kind="rbf", sigma=0.7))
    np.testing.assert_allclose(k.decision_function(X), f,
                               rtol=2e-5, atol=2e-5)


def test_padded_biased_linear_parity():
    """cfg.add_bias + cfg.pad_features: the serving cell's in-cell prep
    (bias FIRST, then zero columns — the fit-time order) matches the
    host oracle."""
    X, y = _problem("CLS", d=13)  # 13 + 1 bias -> pad to 16
    svm = PEMSVM(SVMConfig(max_iters=25, pad_features=8))
    svm.fit(X, y)
    w = np.asarray(svm._weights)
    assert w.shape[0] == 16
    Xb = np.concatenate([X, np.ones((len(X), 1), np.float32)], 1)
    Xb = pad_features_to(Xb, 8)
    np.testing.assert_allclose(svm.decision_function(X),
                               Xb.astype(np.float32) @ w,
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- no-retrace
def test_no_retrace_at_seen_bucket():
    """Repeat decision_function/serve calls at a seen bucket shape
    compile exactly once — and a SECOND model of the same configuration
    reuses the shared cell with zero new compilations (weight paging:
    residency is a weight upload, not a recompile)."""
    X, y = _problem("CLS", n=300, d=19)  # distinctive D
    svm = PEMSVM(SVMConfig(max_iters=20))
    svm.fit(X, y)
    s = svm.scorer()
    t0 = s.traces
    svm.decision_function(X[:90])       # bucket 128: traces once
    t1 = s.traces
    assert t1 - t0 <= 1
    for n in (90, 90, 17, 128, 1):      # all land in the 128 bucket
        svm.decision_function(X[:n])
    assert s.traces == t1, "retraced at a seen bucket shape"
    assert svm.scorer() is s, "scorer rebuilt without a refit"

    svm2 = PEMSVM(SVMConfig(max_iters=20))
    svm2.fit(X, y)
    assert svm2.scorer() is not s
    svm2.decision_function(X[:50])
    assert svm2.scorer().traces == t1, "same-config model recompiled"

    svm.fit(X, y)                       # refit invalidates the cache
    assert svm.scorer() is not s
    svm.decision_function(X[:90])       # ... but still no new trace
    assert svm.scorer().traces == t1


def test_nystrom_no_retrace():
    ny, X = _fit("CLS", "nystrom")
    s = ny.scorer()
    ny.decision_function(X[:40])
    t = s.traces
    for n in (40, 128, 3):
        ny.decision_function(X[:n])
    assert s.traces == t


# ---------------------------------------------------------- uncertainty
def _host_std_oracle(phi, P):
    """sqrt(diag(phi P^{-1} phi^T)) in float64 — the Sigma-quadratic-
    form oracle the served uncertainty head must match."""
    sol = np.linalg.solve(P, phi.astype(np.float64).T)
    return np.sqrt(np.sum(phi.astype(np.float64).T * sol, axis=0))


def test_mc_uncertainty_linear_vs_sigma_oracle():
    X, y = _problem("CLS", n=500, d=9)
    cfg = SVMConfig(max_iters=30, lam=0.5)
    svm = PEMSVM(cfg)
    svm.fit(X, y)
    sc = SVMScorer(svm.export_servable(posterior_from=(X, y)))
    margin, std = sc.score_with_std(X[:200])
    assert np.array_equal(margin, svm.decision_function(X[:200]))

    # Independent host reconstruction of P = lam I + S at the fitted w.
    Xb = np.concatenate([X, np.ones((len(X), 1), np.float32)], 1)
    w = np.asarray(svm._weights, np.float64)
    gamma = np.maximum(np.abs(1.0 - y.astype(np.float64) * (Xb @ w)),
                       cfg.eps)
    S = (Xb.astype(np.float64) * (1.0 / gamma)[:, None]).T @ Xb
    K = S.shape[0]
    P = S + cfg.lam * np.eye(K)
    P = 0.5 * (P + P.T)
    P += cfg.jitter * (np.trace(P) / K) * np.eye(K)
    np.testing.assert_allclose(std, _host_std_oracle(Xb[:200], P),
                               rtol=2e-3, atol=1e-6)
    assert np.all(std > 0)


def test_mc_uncertainty_nystrom_vs_sigma_oracle():
    ny, X = _fit("CLS", "nystrom")
    _, y = _problem("CLS")
    cfg = ny.svm.config
    sc = SVMScorer(ny.export_servable(posterior_from=(X, y)))
    margin, std = sc.score_with_std(X[:150])
    assert np.array_equal(margin, ny.decision_function(X[:150]))

    phi = ny._phi(X, add_bias=True)  # host f64 oracle, bias LAST
    w = np.asarray(ny.svm._weights, np.float64)
    gamma = np.maximum(np.abs(1.0 - y.astype(np.float64) * (phi @ w)),
                       cfg.eps)
    S = (phi.astype(np.float64) * (1.0 / gamma)[:, None]).T @ phi
    K = S.shape[0]
    P = S + cfg.lam * np.eye(K)
    P = 0.5 * (P + P.T)
    P += cfg.jitter * (np.trace(P) / K) * np.eye(K)
    # f32 device featurization vs f64 host phi: wider tolerance.
    np.testing.assert_allclose(std, _host_std_oracle(phi[:150], P),
                               rtol=5e-2, atol=1e-6)


def test_mlt_posterior_rejected():
    svm, X = _fit("MLT", "linear")
    _, y = _problem("MLT")
    with pytest.raises(NotImplementedError):
        svm.export_servable(posterior_from=(X, y))


# ------------------------------------------------------ score convention
def test_score_higher_is_better_both_directions():
    X, y = _problem("SVR")
    good = PEMSVM(SVMConfig(task="SVR", lam=0.1, max_iters=40))
    good.fit(X, y)
    bad = PEMSVM(SVMConfig(task="SVR", lam=200.0, max_iters=3,
                           min_iters=1))
    bad.fit(X, y)
    assert good.rmse(X, y) < bad.rmse(X, y)      # lower error is better
    assert good.score(X, y) > bad.score(X, y)    # higher score is better
    assert good.score(X, y) == -good.rmse(X, y)

    Xc, yc = _problem("CLS")
    cls = PEMSVM(SVMConfig(max_iters=25))
    cls.fit(Xc, yc)
    assert 0.0 <= cls.score(Xc, yc) <= 1.0       # accuracy, unchanged
    with pytest.raises(AssertionError):
        cls.rmse(Xc, yc)                         # rmse is SVR-only


# --------------------------------------------------- pad_features_to
def test_pad_features_width_guard():
    X = np.ones((4, 10), np.float32)
    assert pad_features_to(X, width=10) is X
    assert pad_features_to(X, width=13).shape == (4, 13)
    assert pad_features_to(X, 8).shape == (4, 16)  # multiple mode
    with pytest.raises(ValueError, match="refusing to slice"):
        pad_features_to(X, width=7)
    with pytest.raises(AssertionError):
        pad_features_to(X, 8, width=16)


# --------------------------------------------- feature-order pin (_phi)
def test_phi_host_oracle_matches_device_path():
    """NystromSVM._phi (host f64) and the device phi path agree on
    add_bias ordering: projected features first, bias column LAST."""
    from repro.kernels import ops

    ny, X = _fit("CLS", "nystrom")
    host = ny._phi(X[:64], add_bias=True)
    assert np.array_equal(host[:, -1], np.ones(64, np.float32))
    dev = np.asarray(ops.nystrom_phi(
        X[:64], ny._landmarks, ny._proj, None, sigma=ny.sigma,
        kind=ny.kernel_kind, add_bias=True, backend="ref"))
    np.testing.assert_allclose(host, dev, rtol=2e-4, atol=2e-5)
    # no-bias default stays the bare projection width
    assert ny._phi(X[:5]).shape[1] == ny._proj.shape[1]


# ------------------------------------------------------------ residency
def test_phi_never_materialized_gate():
    ny, X = _fit("CLS", "nystrom")
    sc = ny.scorer()
    assert phi_never_materialized(sc, 512)
    lin, _ = _fit("CLS", "linear")
    assert phi_never_materialized(lin.scorer(), 512)


# ---------------------------------------------------------- weight pager
def test_weight_pager_lru_and_stale_eviction():
    svm, X = _fit("CLS", "linear")
    base = svm.export_servable()
    pager = WeightPager(max_resident=2)
    for name in ("a", "b", "c"):
        pager.register(ServableModel(
            task=base.task, weights=base.weights,
            n_outputs=base.n_outputs, n_features=base.n_features,
            add_bias=base.add_bias, name=name))
    assert pager.scorer("a") is pager.scorer("a")
    assert pager.hits == 1 and pager.misses == 1
    pager.scorer("b")
    pager.scorer("c")                       # evicts "a" (LRU)
    assert pager.resident_names == ["b", "c"]
    assert pager.evictions == 1
    s_b = pager.scorer("b")
    pager.register(ServableModel(           # re-register drops stale
        task=base.task, weights=base.weights * 2.0,
        n_outputs=base.n_outputs, n_features=base.n_features,
        add_bias=base.add_bias, name="b"))
    s_b2 = pager.scorer("b")
    assert s_b2 is not s_b
    assert pager.resident_bytes > 0
    with pytest.raises(KeyError):
        pager.scorer("nope")
    # many tenants, one cell: scoring through different tenants shares
    # the compiled cell, so the bits match when weights match
    assert np.array_equal(pager.scorer("a").score(X[:32]),
                          pager.scorer("c").score(X[:32]))


# ------------------------------------------------------------ serve loop
def test_serve_loop_threaded_and_errors():
    svm, X = _fit("CLS", "linear")
    pager = WeightPager()
    pager.register(svm.export_servable(name="m"))
    loop = ServeLoop(pager, max_wait_ms=1.0).start()
    try:
        futs = [loop.submit("m", X[i * 20:(i + 1) * 20])
                for i in range(8)]
        bad = loop.submit("missing", X[:4])
        outs = [f.result(timeout=10) for f in futs]
        with pytest.raises(KeyError):
            bad.result(timeout=10)
    finally:
        loop.stop()
    served = np.concatenate(outs)[:, 0]
    assert np.array_equal(served, svm.decision_function(X[:160]))
    assert loop.n_requests == 8 and loop.n_rows == 160
    assert len(loop.latencies_ms) == 8
    q = loop.latency_quantiles()
    assert q["p50_ms"] is not None and q["p99_ms"] >= q["p50_ms"]


def test_scorer_rejects_wrong_width():
    svm, X = _fit("CLS", "linear")
    with pytest.raises(ValueError, match="expects"):
        svm.scorer().score(X[:5, :-1])


# ------------------------------------ overload behavior (backpressure)
def test_bounded_intake_sheds_with_explicit_rejection():
    """max_queue bounds the intake: a submit against a full queue gets
    an ALREADY-FAILED Future (ServeRejected) — explicit load shedding
    the client can route around, never silent unbounded queueing."""
    svm, X = _fit("CLS", "linear")
    pager = WeightPager()
    pager.register(svm.export_servable(name="m"))
    loop = ServeLoop(pager, max_queue=2)

    f1 = loop.submit("m", X[:4])
    f2 = loop.submit("m", X[4:8])
    f3 = loop.submit("m", X[8:12])             # over capacity
    assert f3.done()                           # failed at submit time
    with pytest.raises(ServeRejected, match="capacity"):
        f3.result()
    assert loop.n_rejected == 1

    assert loop.step() == 2                    # queued pair still serves
    assert np.array_equal(
        np.concatenate([f1.result(timeout=5), f2.result(timeout=5)])[:, 0],
        svm.decision_function(X[:8]))

    f4 = loop.submit("m", X[:2])               # drained: capacity back
    assert loop.step() == 1 and f4.result(timeout=5).shape[0] == 2
    q = loop.latency_quantiles()
    assert q["rejected"] == 1 and q["expired"] == 0


def test_deadline_expires_at_drain_not_in_batch():
    """A request whose deadline passed while queued fails with
    DeadlineExceeded at drain time and never occupies batch rows; the
    co-queued live request is unaffected. Expiry-at-drain keeps the
    behavior deterministic under the synchronous step() drive."""
    svm, X = _fit("CLS", "linear")
    pager = WeightPager()
    pager.register(svm.export_servable(name="m"))
    loop = ServeLoop(pager)

    doomed = loop.submit("m", X[:4], deadline_ms=1.0)
    live = loop.submit("m", X[4:8])            # no deadline
    time.sleep(0.05)
    assert loop.step() == 2                    # both drained...
    assert loop.n_requests == 1                # ...one served
    assert loop.n_expired == 1
    with pytest.raises(DeadlineExceeded, match="expired"):
        doomed.result()
    assert np.array_equal(live.result(timeout=5)[:, 0],
                          svm.decision_function(X[4:8]))
    assert loop.latency_quantiles()["expired"] == 1


def test_default_deadline_applies_and_is_overridable():
    svm, X = _fit("CLS", "linear")
    pager = WeightPager()
    pager.register(svm.export_servable(name="m"))
    loop = ServeLoop(pager, default_deadline_ms=1.0)

    doomed = loop.submit("m", X[:4])           # inherits the default
    patient = loop.submit("m", X[4:8], deadline_ms=60_000.0)
    time.sleep(0.05)
    loop.step()
    with pytest.raises(DeadlineExceeded):
        doomed.result()
    assert patient.result(timeout=5).shape[0] == 4
    assert loop.n_expired == 1
