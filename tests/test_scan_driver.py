"""The chunked lax.scan fit driver vs the per-iteration loop driver.

The scan driver must reproduce the loop driver's semantics exactly —
same key chain, same update-then-check ordering, trace truncated at the
converged iteration — while syncing with the host at most once per
``scan_chunk`` iterations."""
import math

import numpy as np
import pytest

from repro.core import PEMSVM, SVMConfig


def _fit_pair(options, X, y, max_iters=40, **kw):
    scan = PEMSVM(SVMConfig.from_options(options, max_iters=max_iters, **kw))
    loop = PEMSVM(SVMConfig.from_options(options, max_iters=max_iters,
                                         driver="loop", **kw))
    return scan, scan.fit(X, y), loop, loop.fit(X, y)


def test_scan_matches_loop_on_quickstart(blobs):
    """Same objective trace (fp32 tolerance) and same converged accuracy
    as the per-iteration loop on the quickstart problem."""
    X, y = blobs
    scan, rs, loop, rl = _fit_pair("LIN-EM-CLS", X, y, max_iters=100,
                                   lam=1.0)
    assert rs.n_iters == rl.n_iters
    assert rs.converged == rl.converged
    np.testing.assert_allclose(rs.objective, rl.objective, rtol=1e-5)
    np.testing.assert_allclose(rs.weights, rl.weights, rtol=1e-4,
                               atol=1e-5)
    assert scan.score(X, y) == loop.score(X, y)


def test_scan_host_sync_budget(blobs):
    """At most ceil(max_iters / scan_chunk) objective transfers."""
    X, y = blobs
    for max_iters, chunk in ((100, 16), (40, 7), (30, 64)):
        cfg = SVMConfig(max_iters=max_iters, scan_chunk=chunk, tol=0.0,
                        min_iters=max_iters)  # force the full budget
        res = PEMSVM(cfg).fit(X, y)
        assert res.n_host_syncs <= math.ceil(max_iters / chunk), (
            max_iters, chunk, res.n_host_syncs)
        assert res.n_iters == max_iters
        assert len(res.objective) == max_iters


def test_scan_early_stop_truncates_trace(blobs):
    """Convergence mid-chunk: trace and n_iters stop AT the converged
    iteration even though the chunk ran to its end on device."""
    X, y = blobs
    cfg = SVMConfig(max_iters=100, scan_chunk=64)
    res = PEMSVM(cfg).fit(X, y)
    assert res.converged
    assert res.n_iters < 100
    assert len(res.objective) == res.n_iters
    assert res.n_host_syncs <= math.ceil(res.n_iters / 64) + 1


@pytest.mark.parametrize("options,kw", [
    ("LIN-EM-CLS", {}),
    ("LIN-EM-SVR", dict(eps_ins=0.3)),
    ("LIN-EM-MLT", dict(num_classes=3)),
    ("KRN-EM-CLS", dict(lam=0.1, sigma=1.0)),
])
def test_scan_matches_loop_all_em_tasks(options, kw):
    """Deterministic EM: scan and loop traces agree on every task."""
    rng = np.random.default_rng(7)
    N, K = 600, 10
    X = rng.normal(size=(N, K)).astype(np.float32)
    if options.endswith("SVR"):
        y = (X @ rng.normal(size=K)).astype(np.float32)
    elif options.endswith("MLT"):
        y = np.argmax(X @ rng.normal(size=(3, K)).T, 1).astype(np.int32)
    else:
        y = np.where(X @ rng.normal(size=K) > 0, 1.0, -1.0)
    _, rs, _, rl = _fit_pair(options, X, y, max_iters=25, **kw)
    assert rs.n_iters == rl.n_iters
    np.testing.assert_allclose(rs.objective, rl.objective, rtol=1e-4,
                               atol=1e-4 * max(1.0, abs(rl.objective[0])))


@pytest.mark.parametrize("options", ["LIN-MC-CLS", "LIN-MC-SVR",
                                     "LIN-MC-MLT", "KRN-MC-CLS"])
def test_scan_mc_tasks_match_loop_start_and_quality(options, blobs):
    """MC chains are chaotic in fp32 (in-scan fusion reassociates sums),
    so demand key-chain identity via the first iteration's objective and
    equivalent converged quality, not trace-long equality."""
    rng = np.random.default_rng(3)
    if options.endswith("SVR"):
        X = rng.normal(size=(600, 10)).astype(np.float32)
        y = (X @ rng.normal(size=10)).astype(np.float32)
        kw = dict(eps_ins=0.3)
    elif options.endswith("MLT"):
        X = rng.normal(size=(600, 10)).astype(np.float32)
        y = np.argmax(X @ rng.normal(size=(3, 10)).T, 1).astype(np.int32)
        kw = dict(num_classes=3)
    elif options.startswith("KRN"):
        from repro.data import make_circles
        X, y = make_circles(250)
        kw = dict(lam=0.1, sigma=0.7)
    else:
        X, y = blobs
        kw = {}
    scan, rs, loop, rl = _fit_pair(options, X, y, max_iters=35, **kw)
    np.testing.assert_allclose(rs.objective[0], rl.objective[0], rtol=1e-3)
    s_scan, s_loop = scan.score(X, y), loop.score(X, y)
    if options.endswith("SVR"):
        assert abs(s_scan - s_loop) < 0.1, (s_scan, s_loop)
    else:
        assert abs(s_scan - s_loop) < 0.05, (s_scan, s_loop)
    # posterior averaging must be in effect in both drivers
    assert not np.allclose(rs.weights, rs.last_sample)


def test_scan_mc_average_matches_loop_exactly_when_trajectory_agrees(blobs):
    """On a short deterministic-burnin run the two drivers share the key
    chain; the running averages must then agree to fp32."""
    X, y = blobs
    _, rs, _, rl = _fit_pair("LIN-MC-CLS", X, y, max_iters=14,
                             min_iters=14, burnin=10)
    np.testing.assert_allclose(rs.weights, rl.weights, rtol=5e-4,
                               atol=5e-4)


def test_scan_chunk_size_invariance(blobs):
    """The chunking must be invisible: different scan_chunk values give
    the same trace."""
    X, y = blobs
    traces = []
    for chunk in (1, 5, 16, 128):
        res = PEMSVM(SVMConfig(max_iters=30, min_iters=30,
                               scan_chunk=chunk)).fit(X, y)
        traces.append(np.array(res.objective))
    for t in traces[1:]:
        np.testing.assert_allclose(t, traces[0], rtol=1e-6)


def test_k_shard_indivisible_K_raises():
    """_k_block must refuse (not silently truncate) K % axis_size != 0.

    Single-device check of the validation logic via direct call."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.core.linear import _k_block

    mesh = make_mesh((1,), ("model",))

    def f(x):
        return jnp.asarray(_k_block(x.shape[-1], "model")[0])

    # K=7 divisible by axis size 1 -> fine
    g = shard_map(f, mesh=mesh, in_specs=(P(None, None),),
                  out_specs=P(), check_vma=False)
    assert int(jax.jit(g)(jnp.zeros((4, 7)))) == 0