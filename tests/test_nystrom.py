"""Nystrom-approximated kernel SVM (the paper's Sec-4.3 open question).

PR-3 rebuilt NystromSVM on the fused featurize-and-accumulate kernels:
featurization happens ON DEVICE inside the chunk-callable statistics, so
the scan and stream drivers both serve the nonlinear path. These tests
cover the delegate-config contract, the one-time projection cache, fused
vs host-phi fit parity, and stream vs resident parity across tasks.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import NystromSVM, PEMSVM, SVMConfig
from repro.core.nystrom import nystrom_features, nystrom_projection
from repro.data import make_circles


def test_nystrom_features_approximate_gram():
    from repro.core.kernel import gram_matrix
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 5)).astype(np.float32)
    phi = nystrom_features(X, X[:80], sigma=1.5)   # m=80 landmarks
    K_exact = np.asarray(gram_matrix(jnp.asarray(X), jnp.asarray(X),
                                     sigma=1.5))
    K_apx = phi @ phi.T
    err = np.abs(K_apx - K_exact).mean()
    assert err < 0.05, err


def test_nystrom_matches_exact_krn_accuracy():
    X, y = make_circles(600, seed=1)
    cfg = SVMConfig(formulation="KRN", lam=0.1, sigma=0.7, max_iters=40)
    exact = PEMSVM(cfg)
    exact.fit(X, y)
    ny = NystromSVM(cfg, n_landmarks=60)
    ny.fit(X, y)
    assert ny.score(X, y) >= exact.score(X, y) - 0.02


def test_nystrom_scales_past_exact_krn():
    """At N=4000 the exact N x N Gram has 16M entries; Nystrom runs the
    LIN solver on (N, ~64) features."""
    X, y = make_circles(4000, seed=2)
    ny = NystromSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7,
                              max_iters=30))
    res = ny.fit(X, y)
    assert ny.score(X, y) > 0.98
    assert res.n_iters <= 30


def test_nystrom_mc_variant():
    X, y = make_circles(800, seed=3)
    ny = NystromSVM(SVMConfig(formulation="KRN", algorithm="MC", lam=0.1,
                              sigma=0.7, max_iters=40), n_landmarks=50)
    ny.fit(X, y)
    assert ny.score(X, y) > 0.97


# ------------------------------------------------ delegate config contract
def test_delegate_config_propagates_every_field():
    """NystromSVM builds its LIN delegate with dataclasses.replace, so
    NO config field is silently dropped — driver, scan_chunk,
    chunk_rows, prefetch, jitter, k_shard_axis, and any field added
    later all carry over. Only the three phi-mode fields are
    overridden."""
    cfg = SVMConfig(formulation="KRN", algorithm="MC", lam=0.37,
                    eps=1e-3, num_classes=2, kernel="rbf", sigma=0.9,
                    max_iters=77, min_iters=7, patience=3, tol=2e-3,
                    driver="stream", scan_chunk=11, chunk_rows=123,
                    prefetch=5, burnin=4, jitter=3e-5,
                    triangle_reduce=False, reduce_dtype="bfloat16",
                    backend="ref", seed=42, k_shard_axis="model")
    ny = NystromSVM(cfg)
    overridden = {"formulation": "LIN", "add_bias": False}
    delegate = ny.svm.config
    assert delegate.phi_spec is not None
    assert delegate.phi_spec.sigma == cfg.sigma
    assert delegate.phi_spec.kind == cfg.kernel
    for f in dataclasses.fields(SVMConfig):
        if f.name == "phi_spec":
            continue
        want = overridden.get(f.name, getattr(cfg, f.name))
        got = getattr(delegate, f.name)
        assert got == want, (f.name, got, want)


def test_projection_cached_eigh_runs_once(monkeypatch):
    """fit computes K_mm^{-1/2} ONCE; predict/decision_function/score
    reuse the cache (the old implementation refactorized per call)."""
    calls = []
    orig = np.linalg.eigh

    def counting_eigh(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    monkeypatch.setattr(np.linalg, "eigh", counting_eigh)
    X, y = make_circles(300, seed=5)
    ny = NystromSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7,
                              max_iters=10, min_iters=10),
                    n_landmarks=40)
    ny.fit(X, y)
    ny.predict(X[:50])
    ny.decision_function(X[:50])
    ny.score(X[:50], y[:50])
    assert len(calls) == 1, f"eigh ran {len(calls)} times"
    np.testing.assert_allclose(
        ny._proj, nystrom_projection(ny._landmarks, sigma=0.7).astype(
            np.float32), rtol=1e-5, atol=1e-6)


# ------------------------------------------------- fused vs host-phi parity
def _parity_problem(seed=0, N=2048, D=16):
    """Well-conditioned phi-space posterior (lam=1, wide rbf): the
    chunked-vs-resident difference is pure fp32 reassociation noise
    through a modest condition number, so 1e-4 weight parity is a real
    bound rather than luck (see DESIGN.md §Perf/Nystrom exactness)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(N, D)).astype(np.float32)
    wt = rng.normal(size=D)
    y = np.where(np.tanh(X @ wt) + 0.3 * rng.normal(size=N) > 0,
                 1.0, -1.0).astype(np.float32)
    return X, y


def test_fused_fit_matches_host_phi_baseline():
    """EM acceptance: the on-device fused path lands within 1e-4 of the
    float64 host-featurized LIN fit on the SAME landmarks."""
    X, y = _parity_problem()
    cfg = SVMConfig(formulation="KRN", lam=1.0, sigma=3.0, eps=1e-2,
                    max_iters=20, min_iters=20)
    ny = NystromSVM(cfg, n_landmarks=64)
    r_fused = ny.fit(X, y)

    phi_host = nystrom_features(X, ny._landmarks, sigma=3.0)
    base_cfg = dataclasses.replace(ny.svm.config, phi_spec=None,
                                   add_bias=True)
    base = PEMSVM(base_cfg)
    r_host = base.fit(phi_host, y)

    rel = (np.abs(r_fused.weights - r_host.weights).max()
           / np.abs(r_host.weights).max())
    assert rel <= 1e-4, rel
    assert abs(ny.score(X, y) - base.score(phi_host, y)) <= 1e-3


# ------------------------------------------------ stream vs resident parity
# EM is deterministic: tight bound. MC forks through the IG sampler's
# accept-reject branch on lsb-level margin differences (same analysis as
# tests/test_streaming.py), so short chains + looser bounds.
@pytest.mark.parametrize("options,kw,iters,bound", [
    ("KRN-EM-CLS", {}, 20, 1e-4),
    ("KRN-EM-SVR", dict(eps_ins=0.3), 20, 1e-4),
    ("KRN-MC-CLS", dict(burnin=6), 12, 2e-3),
    ("KRN-MC-SVR", dict(eps_ins=0.3, burnin=6), 12, 2e-3),
])
def test_nystrom_stream_matches_resident(options, kw, iters, bound):
    task = options.split("-")[-1]
    X, y = _parity_problem(seed=7, N=1536)
    if task == "SVR":
        rng = np.random.default_rng(8)
        y = np.tanh(X @ rng.normal(size=X.shape[1])).astype(np.float32)
    kw = {"lam": 1.0, "sigma": 3.0, "eps": 1e-2, **kw}
    kw["max_iters"] = kw["min_iters"] = iters
    resident = NystromSVM(SVMConfig.from_options(options, **kw),
                          n_landmarks=48)
    streamed = NystromSVM(SVMConfig.from_options(
        options, driver="stream", chunk_rows=192, **kw), n_landmarks=48)
    rr = resident.fit(X, y)
    rs = streamed.fit(X, y)
    np.testing.assert_array_equal(streamed._landmarks,
                                  resident._landmarks)
    rel = (np.abs(rs.weights - rr.weights).max()
           / max(1e-12, np.abs(rr.weights).max()))
    assert rel <= bound, (options, rel)
    np.testing.assert_allclose(rs.objective[0], rr.objective[0],
                               rtol=1e-4)
    assert abs(streamed.score(X, y) - resident.score(X, y)) < 1e-2


def test_nystrom_mlt_stream_and_resident():
    """KRN-MLT (new capability: the exact solver is CLS-only) — the
    phi-space Crammer-Singer sweep works resident and streamed."""
    rng = np.random.default_rng(9)
    N, D, M = 900, 8, 3
    X = rng.normal(size=(N, D)).astype(np.float32)
    labels = np.argmax(np.abs(X @ rng.normal(size=(M, D)).T), 1
                       ).astype(np.int32)
    kw = dict(formulation="KRN", task="MLT", num_classes=M, lam=1.0,
              sigma=3.0, eps=1e-2, max_iters=10, min_iters=10)
    resident = NystromSVM(SVMConfig(**kw), n_landmarks=48)
    rr = resident.fit(X, labels)
    assert resident.score(X, labels) > 0.75
    streamed = NystromSVM(SVMConfig(driver="stream", chunk_rows=128,
                                    **kw), n_landmarks=48)
    rs = streamed.fit(X, labels)
    rel = (np.abs(rs.weights - rr.weights).max()
           / np.abs(rr.weights).max())
    assert rel <= 1e-3, rel


def test_nystrom_stream_fit_libsvm_out_of_core(tmp_path):
    """File -> reservoir landmarks -> streamed featurize-and-accumulate
    == host-phi resident fit on the same landmarks, with device input
    residency bounded by (prefetch + 2) RAW D-wide chunks."""
    from repro.data import save_libsvm

    X, y = _parity_problem(seed=11, N=1200, D=10)
    p = str(tmp_path / "ny.libsvm")
    save_libsvm(p, X, y)
    cfg = SVMConfig(formulation="KRN", driver="stream", chunk_rows=128,
                    prefetch=2, lam=1.0, sigma=3.0, eps=1e-2,
                    max_iters=12, min_iters=12)
    ny = NystromSVM(cfg, n_landmarks=40)
    res = ny.fit_libsvm(p, n_features=10)

    # residency: (prefetch+2) chunks of RAW rows — D-wide, not m-wide
    chunk_bytes = 128 * 10 * 4 + 2 * 128 * 4
    assert 0 < res.peak_input_bytes <= 4 * chunk_bytes

    phi_host = nystrom_features(X, ny._landmarks, sigma=3.0)
    base = PEMSVM(dataclasses.replace(ny.svm.config, phi_spec=None,
                                      add_bias=True, driver="scan"))
    r_host = base.fit(phi_host, y)
    rel = (np.abs(res.weights - r_host.weights).max()
           / np.abs(r_host.weights).max())
    assert rel <= 1e-4, rel


def test_nystrom_predict_uses_delegate_featurization():
    """decision_function on raw X == LIN decision on host phi features
    (the delegate featurizes on device with the cached projection)."""
    X, y = _parity_problem(seed=13, N=600, D=6)
    ny = NystromSVM(SVMConfig(formulation="KRN", lam=1.0, sigma=2.0,
                              max_iters=10, min_iters=10),
                    n_landmarks=32)
    ny.fit(X, y)
    f_dev = ny.decision_function(X[:100])
    phi = ny._phi(X[:100])
    w = ny.svm._weights
    f_host = np.concatenate([phi, np.ones((100, 1), np.float32)], 1) @ w
    np.testing.assert_allclose(f_dev, f_host, rtol=1e-3, atol=1e-4)
