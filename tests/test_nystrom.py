"""Nystrom-approximated kernel SVM (the paper's Sec-4.3 open question)."""
import numpy as np

from repro.core import NystromSVM, PEMSVM, SVMConfig
from repro.core.nystrom import nystrom_features
from repro.data import make_circles


def test_nystrom_features_approximate_gram():
    from repro.core.kernel import gram_matrix
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 5)).astype(np.float32)
    phi = nystrom_features(X, X[:80], sigma=1.5)   # m=80 landmarks
    K_exact = np.asarray(gram_matrix(jnp.asarray(X), jnp.asarray(X),
                                     sigma=1.5))
    K_apx = phi @ phi.T
    err = np.abs(K_apx - K_exact).mean()
    assert err < 0.05, err


def test_nystrom_matches_exact_krn_accuracy():
    X, y = make_circles(600, seed=1)
    cfg = SVMConfig(formulation="KRN", lam=0.1, sigma=0.7, max_iters=40)
    exact = PEMSVM(cfg)
    exact.fit(X, y)
    ny = NystromSVM(cfg, n_landmarks=60)
    ny.fit(X, y)
    assert ny.score(X, y) >= exact.score(X, y) - 0.02


def test_nystrom_scales_past_exact_krn():
    """At N=4000 the exact N x N Gram has 16M entries; Nystrom runs the
    LIN solver on (N, ~64) features."""
    X, y = make_circles(4000, seed=2)
    ny = NystromSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7,
                              max_iters=30))
    res = ny.fit(X, y)
    assert ny.score(X, y) > 0.98
    assert res.n_iters <= 30


def test_nystrom_mc_variant():
    X, y = make_circles(800, seed=3)
    ny = NystromSVM(SVMConfig(formulation="KRN", algorithm="MC", lam=0.1,
                              sigma=0.7, max_iters=40), n_landmarks=50)
    ny.fit(X, y)
    assert ny.score(X, y) > 0.97
