"""Regression tests for the dry-run spec builders (bugs found during the
sweep iterations are pinned here)."""
import jax
import jax.numpy as jnp

from repro import compat
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import specs as sp
from repro.sharding import ShardingCtx


@pytest.fixture(scope="module")
def ctx1():
    # single-device mesh: divisibility checks still exercise the code
    mesh = compat.make_mesh((1, 1), ("data", "model"),
                         axis_types=("auto",) * 2)
    return ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                       fsdp_axis="data")


def test_cache_spec_never_shards_period_dim(ctx1):
    """REGRESSION: the stacked-periods dim (80 for qwen2: divisible by
    16!) once grabbed the model axis — the layer scan then gathered the
    whole cache slice every layer (22-49 GB/step observed)."""
    cfg = get_config("qwen2-vl-72b")
    specs, shards = sp.cache_specs(cfg, SHAPES["decode_32k"], ctx1)
    for leaf in jax.tree.leaves(
            shards, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec)):
        assert leaf[0] is None, f"period dim sharded: {leaf}"


def test_cache_spec_seq_over_model(ctx1):
    cfg = get_config("deepseek-67b")
    specs, shards = sp.cache_specs(cfg, SHAPES["decode_32k"], ctx1)
    leaf = jax.tree.leaves(
        shards, is_leaf=lambda x: isinstance(
            x, jax.sharding.PartitionSpec))[0]
    # (periods, B, S, KVH, dh): B over dp, S over tp
    # (PartitionSpec normalizes 1-tuples to the bare axis name)
    assert leaf[1] in ("data", ("data",)) and leaf[2] == "model", leaf


def test_batch_specs_cover_modalities(ctx1):
    for arch, key in [("yi-34b", "tokens"), ("qwen2-vl-72b", "embeds"),
                      ("whisper-small", "frames")]:
        cfg = get_config(arch)
        specs, shards = sp.batch_specs(cfg, SHAPES["train_4k"], ctx1,
                                       with_labels=True)
        assert key in specs and "labels" in specs
        B = SHAPES["train_4k"].global_batch
        assert specs["labels"].shape == (B, 4096)


def test_opt_state_mirrors_params(ctx1):
    cfg = get_config("smollm-135m")
    pstructs, pspecs = sp.param_struct_specs(cfg, ctx1)
    ostructs, ospecs = sp.opt_state_specs(pstructs, pspecs)
    assert jax.tree.structure(ostructs["m"]) == jax.tree.structure(pstructs)
    assert jax.tree.structure(ospecs["v"]) == jax.tree.structure(pspecs)


def test_serve_param_dtype_override(ctx1):
    cfg = get_config("smollm-135m")
    pstructs, _ = sp.param_struct_specs(cfg, ctx1, dtype="bfloat16")
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(pstructs)
               if jnp.issubdtype(x.dtype, jnp.floating))
