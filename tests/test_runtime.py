"""Runtime reliability units: straggler monitor, elastic schedule,
fault injectors, retrying loader, prefetcher error propagation.

The end-to-end kill/resume parity proofs live in test_elastic_fit.py;
this file pins the small mechanisms those proofs compose."""
import numpy as np
import pytest

from repro.data import ChunkPrefetcher
from repro.data.pipeline import retrying_chunks
from repro.runtime import faults
from repro.runtime.elastic import scale_batch_schedule
from repro.runtime.policy import FaultPolicy
from repro.runtime.straggler import StepTimeMonitor


# ------------------------------------------------------ StepTimeMonitor
def test_monitor_warmup_never_flags():
    m = StepTimeMonitor(warmup_steps=3, threshold=1.5)
    # grossly slow steps during warmup are absorbed, not flagged
    assert not any(m.observe(i, 100.0) for i in range(1, 4))
    assert m.events == []
    assert m.ema > 0.0


def test_monitor_flags_and_records():
    m = StepTimeMonitor(warmup_steps=2, threshold=2.0)
    m.observe(1, 1.0)
    m.observe(2, 1.0)
    assert m.ema == pytest.approx(1.0)
    assert not m.observe(3, 1.9)      # under threshold x EMA
    assert m.observe(4, 2.5)          # over
    (step, seconds, ema), = m.events
    assert step == 4 and seconds == 2.5 and ema == pytest.approx(
        m.ema, rel=0.2)
    assert m.summary()["straggler_events"] == 1


def test_monitor_straggler_does_not_poison_ema():
    """A flagged step must NOT move the EMA — otherwise one straggler
    raises the baseline and masks the next one."""
    m = StepTimeMonitor(warmup_steps=1, threshold=2.0, ema_decay=0.9)
    m.observe(1, 1.0)
    ema_before = m.ema
    assert m.observe(2, 50.0)         # straggler
    assert m.ema == ema_before        # untouched
    assert m.observe(3, 50.0)         # still flagged against old EMA
    # healthy step moves it
    m.observe(4, 1.0)
    assert m.ema != ema_before or m.ema == pytest.approx(1.0)


def test_monitor_from_policy():
    pol = FaultPolicy(straggler_threshold=3.5, straggler_warmup=7)
    m = StepTimeMonitor.from_policy(pol)
    assert m.threshold == 3.5 and m.warmup_steps == 7


# -------------------------------------------------- scale_batch_schedule
def test_scale_batch_keep_global():
    gb, lr = scale_batch_schedule(1024, old_workers=8, new_workers=4)
    assert (gb, lr) == (1024, 1.0)
    with pytest.raises(AssertionError):
        scale_batch_schedule(1000, old_workers=8, new_workers=3)


def test_scale_batch_keep_per_worker():
    gb, lr = scale_batch_schedule(1024, old_workers=8, new_workers=4,
                                  keep_global=False)
    assert gb == 128 * 4
    assert lr == pytest.approx(0.5)


# ---------------------------------------------------------- fault tools
def _ten_chunks():
    for i in range(10):
        yield (np.full((2,), i, np.float32),)


def test_kill_after_chunks_counts_across_iterators():
    killed = faults.kill_after_chunks(_ten_chunks, 13)
    got = [int(c[0][0]) for c in killed()]          # pass 1: 10 chunks
    assert got == list(range(10))
    it2 = killed()                                   # pass 2: 3 more
    assert [int(next(it2)[0][0]) for _ in range(3)] == [0, 1, 2]
    with pytest.raises(faults.SimulatedPreemption):
        next(it2)


def test_kill_at_iteration_and_compose():
    log = []
    hook = faults.compose_hooks(log.append, faults.kill_at_iteration(3))
    hook(1), hook(2)
    with pytest.raises(faults.SimulatedPreemption):
        hook(3)
    assert log == [1, 2, 3]


def test_io_error_every_nth_persists_across_factories():
    # positions 3 and 7 each fail twice; a restarting consumer hits the
    # first still-failing position per pass -> 4 failing passes, then clean
    flaky = faults.io_error_every_nth(_ten_chunks, nth=4, times=2)
    for expect_fail in (True, True, True, True, False):
        try:
            n = sum(1 for _ in flaky())
        except IOError:
            assert expect_fail
        else:
            assert not expect_fail and n == 10


# ------------------------------------------------------ retrying_chunks
def test_retrying_chunks_drains_flaky_source():
    flaky = faults.io_error_every_nth(_ten_chunks, nth=3, times=2)
    naps = []
    got = list(retrying_chunks(
        lambda done: __import__("itertools").islice(flaky(), done, None),
        retries=10, backoff=0.01, sleep=naps.append))
    assert [int(c[0][0]) for c in got] == list(range(10))
    # 3 flaky positions x 2 failures each = 6 retries, backoff doubling
    assert len(naps) == 6
    assert naps[0] == pytest.approx(0.01)
    assert naps[1] == pytest.approx(0.02)  # consecutive failure doubles


def test_retrying_chunks_exhausts_budget():
    flaky = faults.io_error_every_nth(_ten_chunks, nth=3, times=99)
    with pytest.raises(IOError):
        list(retrying_chunks(
            lambda done: __import__("itertools").islice(flaky(), done,
                                                        None),
            retries=3, backoff=0.0, sleep=lambda s: None))


def test_retrying_chunks_retries_open_failure():
    """The factory call itself is inside the retry net (opening the
    file can fail too, not just reading a chunk)."""
    attempts = [0]

    def factory(done):
        attempts[0] += 1
        if attempts[0] <= 2:
            raise IOError("open failed")
        import itertools
        return itertools.islice(_ten_chunks(), done, None)

    got = list(retrying_chunks(factory, retries=3, backoff=0.0,
                               sleep=lambda s: None))
    assert len(got) == 10 and attempts[0] == 3


def test_retrying_chunks_foreign_exception_propagates():
    def bad(done):
        def gen():
            yield (np.zeros(1),)
            raise ValueError("not an IO problem")
        return gen()

    with pytest.raises(ValueError):
        list(retrying_chunks(bad, retries=5, backoff=0.0,
                             sleep=lambda s: None))


# -------------------------------------- ChunkPrefetcher error forwarding
def test_prefetcher_propagates_worker_exception():
    """Regression: a loader exception inside the prefetch thread must
    re-raise at the consumer's iteration site — not hang the consumer
    on q.get() and not vanish into the thread."""
    def chunks():
        yield (np.zeros((4,), np.float32),)
        yield (np.ones((4,), np.float32),)
        raise IOError("disk vanished mid-file")

    pf = ChunkPrefetcher(chunks(), depth=2)
    got = []
    with pytest.raises(IOError, match="disk vanished"):
        for c in pf:
            got.append(c)
    assert len(got) == 2              # everything before the fault arrived


def test_prefetcher_propagates_preemption():
    killed = faults.kill_after_chunks(_ten_chunks, 4)
    with pytest.raises(faults.SimulatedPreemption):
        for _ in ChunkPrefetcher(killed(), depth=2):
            pass


def test_prefetcher_normal_completion_unchanged():
    out = list(ChunkPrefetcher(_ten_chunks(), depth=2))
    assert len(out) == 10
    assert ChunkPrefetcher(_ten_chunks(), depth=2).max_resident_bytes == 0
