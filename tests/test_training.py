"""Training substrate: optimizer, chunked xent, microbatching."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import reduce_cfg
from repro.configs import get_config
from repro.models import build_model
from repro.training import (AdamWConfig, apply_updates, init_state,
                            chunked_softmax_xent, make_train_step,
                            init_train_state, schedule)
from repro.training.optimizer import global_norm


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=300,
                      weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = init_state(params)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = apply_updates(cfg, params, g, state)
    np.testing.assert_allclose(np.asarray(params["w"]), target, atol=0.05)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) < 0.2
    np.testing.assert_allclose(float(schedule(cfg, jnp.int32(9))), 1.0,
                               rtol=0.01)
    assert abs(float(schedule(cfg, jnp.int32(100))) - 0.1) < 1e-3


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = init_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = apply_updates(cfg, params, g, state)
    assert float(metrics["grad_norm"]) == 200.0
    assert float(global_norm(g)) == 200.0


def test_chunked_xent_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, D, V = 2, 32, 16, 50
    h = jax.random.normal(key, (B, S, D))
    w = jax.random.normal(jax.random.PRNGKey(1), (V, D))
    labels = jax.random.randint(key, (B, S), 0, V)
    got = chunked_softmax_xent(h, w, labels, chunk=8)
    logits = jnp.einsum("bsd,vd->bsv", h, w)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_microbatch_equivalence():
    """Gradient accumulation must reproduce the single-pass update."""
    cfg = reduce_cfg(get_config("smollm-135m"), n_layers=2)
    m = build_model(cfg, q_chunk=16, kv_chunk=16)
    key = jax.random.PRNGKey(0)
    state1 = init_train_state(m, key)
    state2 = jax.tree.map(lambda x: x, state1)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    s1 = jax.jit(make_train_step(m, opt_cfg, loss_chunk=16, microbatches=1))
    s2 = jax.jit(make_train_step(m, opt_cfg, loss_chunk=16, microbatches=2))
    state1, m1 = s1(state1, batch)
    state2, m2 = s2(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # Post-Adam params: near-zero grads get +-lr updates whose sign is
    # sensitive to bf16 summation order, so compare above one LR step.
    lr_step = 2 * opt_cfg.lr
    for a, b in zip(jax.tree.leaves(state1["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=lr_step * 1.5)
