"""stats.py: triangle packing, posterior params, weight draws."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import stats


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(0, 2 ** 20))
def test_triangle_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(k, k)).astype(np.float32)
    S = A + A.T
    packed = stats.triangle_pack(jnp.asarray(S))
    assert packed.shape == (k * (k + 1) // 2,)
    back = stats.triangle_unpack(packed, k)
    np.testing.assert_allclose(np.asarray(back), S, rtol=1e-6)


def test_posterior_params_matches_numpy_solve():
    rng = np.random.default_rng(1)
    K = 24
    X = rng.normal(size=(200, K)).astype(np.float32)
    S = (X.T @ X).astype(np.float32)
    b = rng.normal(size=(K,)).astype(np.float32)
    lam = 0.7
    L, mu = stats.posterior_params(jnp.asarray(S), jnp.asarray(b), lam)
    want = np.linalg.solve(S + lam * np.eye(K), b)
    np.testing.assert_allclose(np.asarray(mu), want, rtol=2e-3, atol=1e-4)


def test_draw_weight_covariance():
    """w ~ N(mu, P^{-1}): empirical covariance must match P^{-1}."""
    rng = np.random.default_rng(2)
    K = 6
    A = rng.normal(size=(K, K))
    P = (A @ A.T + 2 * np.eye(K)).astype(np.float32)
    L = jnp.linalg.cholesky(jnp.asarray(P))
    mu = jnp.zeros((K,))
    keys = jax.random.split(jax.random.PRNGKey(0), 30_000)
    draws = jax.vmap(lambda k: stats.draw_weight(k, L, mu))(keys)
    emp = np.cov(np.asarray(draws).T)
    np.testing.assert_allclose(emp, np.linalg.inv(P), atol=0.06)


def test_reduce_stats_identity_off_mesh():
    S = jnp.eye(5)
    b = jnp.arange(5.0)
    S2, b2 = stats.reduce_stats(S, b, axes=())
    np.testing.assert_allclose(np.asarray(S2), np.eye(5))
    np.testing.assert_allclose(np.asarray(b2), np.arange(5.0))


def test_posterior_scaled_jitter_handles_bad_conditioning():
    """fp32 Gram noise (slightly negative eigenvalues) must not break the
    Cholesky once the relative ridge is applied."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(400, 2)).astype(np.float32)
    from repro.core.kernel import gram_matrix
    G = np.asarray(gram_matrix(jnp.asarray(X), jnp.asarray(X), sigma=0.7))
    S = (G.T @ G).astype(np.float32)
    L, mu = stats.posterior_params(jnp.asarray(S), jnp.asarray(G[:, 0]),
                                   0.1, prior_precision=jnp.asarray(G),
                                   jitter=1e-4)
    assert bool(jnp.all(jnp.isfinite(L))) and bool(jnp.all(jnp.isfinite(mu)))
