"""Kill it. Resume it. Get the same bits.

Every reliability claim in DESIGN.md §Reliability is proven here by
actually preempting a fit (``runtime.faults``) and resuming from the
last committed snapshot:

  * same config + same driver -> resume is BITWISE equal to the
    uninterrupted fit (EM and MC: the checkpoint carries the PRNG
    carry key, and mid-pass snapshots carry the iteration subkey);
  * checkpoints restore across drivers and meshes (the elastic
    contract) to the corresponding whole-fit reassociation band —
    resuming adds no error beyond what changing the layout already
    costs;
  * the budget can be EXTENDED on resume (max_iters is outside the
    config fingerprint); everything semantic is inside it and
    mismatches fail loudly;
  * straggler reactions (record / drop / raise) and the live-weighted
    renormalized reduction behave as documented.

Single-device tests run inline; mesh tests run in subprocesses with
``--xla_force_host_platform_device_count`` (same pattern as
test_kshard_fused.py).
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import NystromSVM, PEMSVM, SVMConfig
from repro.core import resume as resume_mod
from repro.core.linear import SVMData
from repro.runtime import faults
from repro.runtime.policy import FaultPolicy, StragglerError

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_rng = np.random.default_rng(0)
N, K = 257, 9
X = _rng.normal(size=(N, K)).astype(np.float32)
_w_true = _rng.normal(size=K + 1)
Y_CLS = np.where(X @ _w_true[:K] + _w_true[K] > 0, 1.0, -1.0).astype(
    np.float32)
Y_SVR = (X @ _w_true[:K]).astype(np.float32)


def _kill_fit(svm, X, y, hook, **fit_kw):
    """Run a fit that MUST be preempted by ``hook``."""
    with pytest.raises(faults.SimulatedPreemption):
        svm.fit(X, y, fault_hook=hook, **fit_kw)


# ------------------------------------------- same-driver bitwise parity
@pytest.mark.parametrize("algo", ["EM", "MC"])
@pytest.mark.parametrize("task", ["CLS", "SVR"])
def test_stream_kill_resume_bitwise(algo, task, tmp_path):
    """Stream driver, killed between iterations: the resumed trajectory
    is the uninterrupted one, bit for bit — EM (deterministic) AND MC
    (the checkpointed carry key continues the exact chain)."""
    tgt = Y_CLS if task == "CLS" else Y_SVR
    kw = dict(algorithm=algo, task=task, driver="stream", chunk_rows=64,
              max_iters=12, min_iters=12, burnin=3)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, tgt)

    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2, ckpt_chunks=2)
    cfg = SVMConfig(**kw, fault=pol)
    _kill_fit(PEMSVM(cfg), X, tgt, faults.kill_at_iteration(7))
    res = PEMSVM(cfg).fit(X, tgt, resume_from=str(tmp_path))

    assert res.resumed_at is not None and res.resumed_at >= 6
    assert np.array_equal(ref.weights, res.weights)
    assert np.allclose(ref.objective, res.objective)


def _five_chunks():
    """A restartable fit_chunks source: 257 rows padded to 5 x 64."""
    Xp = np.concatenate([X, np.zeros((63, K), np.float32)])
    yp = np.concatenate([Y_CLS, np.zeros(63, np.float32)])
    mp = np.concatenate([np.ones(N, np.float32),
                         np.zeros(63, np.float32)])
    for i0 in range(0, 320, 64):
        yield SVMData(Xp[i0:i0 + 64], yp[i0:i0 + 64], mp[i0:i0 + 64])


def test_midpass_kill_resume_bitwise(tmp_path):
    """Preempt INSIDE a pass (chunk 12 of a 5-chunk/pass stream) with
    per-chunk snapshots on: resume skips the already-folded chunks,
    consumes the saved iteration subkey without re-splitting, and the
    MC chain continues bitwise."""
    kw = dict(algorithm="MC", task="CLS", driver="stream", chunk_rows=64,
              max_iters=8, min_iters=8, burnin=2)
    ref = PEMSVM(SVMConfig(**kw)).fit_chunks(_five_chunks, K)

    d = str(tmp_path)
    pol = FaultPolicy(ckpt_dir=d, ckpt_every=100, ckpt_chunks=1)
    cfg = SVMConfig(**kw, fault=pol)
    with pytest.raises(faults.SimulatedPreemption):
        PEMSVM(cfg).fit_chunks(faults.kill_after_chunks(_five_chunks, 12),
                               K)

    ck = Checkpointer(d)
    payload = resume_mod.load_snapshot(ck)
    assert payload["in_pass"] and payload["chunk_idx"] > 0

    # a mid-pass snapshot is stream-only and chunk_rows-pinned
    with pytest.raises(ValueError, match="driver='stream'"):
        PEMSVM(SVMConfig(algorithm="MC", task="CLS", driver="scan",
                         max_iters=8, min_iters=8, burnin=2, fault=pol)
               ).fit(X, Y_CLS, resume_from=d)
    with pytest.raises(ValueError, match="chunk_rows"):
        PEMSVM(SVMConfig(**{**kw, "chunk_rows": 32}, fault=pol)
               ).fit(X, Y_CLS, resume_from=d)

    res = PEMSVM(cfg).fit_chunks(_five_chunks, K, resume_from=d)
    assert np.array_equal(ref.weights, res.weights)


@pytest.mark.parametrize("algo", ["EM", "MC"])
def test_scan_kill_resume_bitwise(algo, tmp_path):
    """Scan driver checkpoints at host-sync boundaries; killed there,
    it resumes bitwise."""
    kw = dict(algorithm=algo, task="CLS", driver="scan", scan_chunk=4,
              max_iters=12, min_iters=12, burnin=3)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=4)
    cfg = SVMConfig(**kw, fault=pol)
    _kill_fit(PEMSVM(cfg), X, Y_CLS, faults.kill_at_iteration(8))
    res = PEMSVM(cfg).fit(X, Y_CLS, resume_from=str(tmp_path))
    assert np.array_equal(ref.weights, res.weights)


def test_loop_kill_resume_bitwise(tmp_path):
    kw = dict(algorithm="MC", task="CLS", driver="loop", max_iters=10,
              min_iters=10, burnin=2)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=3)
    cfg = SVMConfig(**kw, fault=pol)
    _kill_fit(PEMSVM(cfg), X, Y_CLS, faults.kill_at_iteration(7))
    res = PEMSVM(cfg).fit(X, Y_CLS, resume_from=str(tmp_path))
    assert np.array_equal(ref.weights, res.weights)
    assert res.n_checkpoints >= 1


def test_extend_budget_bitwise(tmp_path):
    """max_iters is OUTSIDE the fingerprint: a finished 5-iteration fit
    resumes into a 10-iteration budget and lands exactly where the
    one-shot 10-iteration fit does."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", min_iters=1,
              tol=1e-12)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=5)
    r1 = PEMSVM(SVMConfig(**kw, max_iters=5, fault=pol)).fit(X, Y_CLS)
    r2 = PEMSVM(SVMConfig(**kw, max_iters=10, fault=pol)).fit(
        X, Y_CLS, resume_from=str(tmp_path))
    ref = PEMSVM(SVMConfig(**kw, max_iters=10)).fit(X, Y_CLS)
    assert (r1.n_iters, r2.n_iters) == (5, 10)
    assert r2.resumed_at == 5
    assert np.array_equal(ref.weights, r2.weights)


def test_resume_step_pins_snapshot(tmp_path):
    """``resume_step`` picks a specific committed step (its id is
    it * 1_000_000 for boundary saves); replaying from iteration 6
    reproduces the donor run bitwise — including the objective
    history carried through the snapshot."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
              min_iters=10)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=3, keep_k=10)
    cfg = SVMConfig(**kw, fault=pol)
    ref = PEMSVM(cfg).fit(X, Y_CLS)                 # commits 3, 6, 9, 10
    res = PEMSVM(cfg).fit(X, Y_CLS, resume_from=str(tmp_path),
                          resume_step=resume_mod.step_id(6))
    assert res.resumed_at == 6
    assert np.array_equal(ref.weights, res.weights)
    assert np.allclose(ref.objective, res.objective)


# ----------------------------------------------- cross-layout elasticity
@pytest.mark.parametrize("target_driver", ["scan", "loop"])
def test_cross_driver_resume(target_driver, tmp_path):
    """A checkpoint written by the stream driver restores into scan and
    loop. Chunked fp32 accumulation reassociates the sums, so parity is
    the stream-vs-resident whole-fit band, not bitwise."""
    kw = dict(algorithm="MC", task="CLS", burnin=2, max_iters=10,
              min_iters=10)
    ref = PEMSVM(SVMConfig(**kw, driver="loop")).fit(X, Y_CLS)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=3)
    _kill_fit(PEMSVM(SVMConfig(**kw, driver="stream", chunk_rows=64,
                               fault=pol)),
              X, Y_CLS, faults.kill_at_iteration(6))
    res = PEMSVM(SVMConfig(**kw, driver=target_driver, scan_chunk=4,
                           fault=pol)).fit(X, Y_CLS,
                                           resume_from=str(tmp_path))
    rel = (np.abs(ref.weights - res.weights).max()
           / np.abs(ref.weights).max())
    assert res.resumed_at is not None
    assert rel < 2e-3, rel


# -------------------------------------------- warm start + decayed stats
def test_warm_start_decay_stream():
    """decay > 0 (stream): the donor's accumulated (S, b) are folded
    into every M-step of the new fit, down-weighted by decay — the
    online/continual-fit warm start. The effective statistics ride on
    FitResult.stats so fits can be chained."""
    kw = dict(algorithm="EM", task="CLS", driver="stream", chunk_rows=64,
              max_iters=6, min_iters=6, decay=0.5)
    donor = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    assert donor.stats is not None
    assert donor.stats["S"].shape == (K + 1, K + 1)
    assert donor.stats["b"].shape == (K + 1,)

    fresh = PEMSVM(SVMConfig(**kw)).fit(X, -Y_CLS)
    warm = PEMSVM(SVMConfig(**kw)).fit(X, -Y_CLS, warm_start=donor)
    assert warm.stats is not None
    assert not np.allclose(fresh.weights, warm.weights)


def test_warm_start_decay_multiclass():
    kw = dict(algorithm="EM", task="MLT", num_classes=3, driver="stream",
              chunk_rows=64, max_iters=4, min_iters=4, decay=0.3)
    ym = _rng.integers(0, 3, size=N)
    donor = PEMSVM(SVMConfig(**kw)).fit(X, ym)
    warm = PEMSVM(SVMConfig(**kw)).fit(X, ym, warm_start=donor)
    assert warm.stats["S"].shape == (3, K + 1, K + 1)
    assert warm.stats["b"].shape == (3, K + 1)


# --------------------------------------------------- guard-rail errors
def test_resume_and_warm_start_mutually_exclusive():
    donor = PEMSVM(SVMConfig(driver="loop", max_iters=2, min_iters=2)
                   ).fit(X, Y_CLS)
    with pytest.raises(ValueError):
        PEMSVM(SVMConfig(driver="loop", max_iters=2, min_iters=2)).fit(
            X, Y_CLS, resume_from="/tmp/anywhere", warm_start=donor)


def test_fingerprint_mismatch_names_field(tmp_path):
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2)
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=4,
              min_iters=4)
    PEMSVM(SVMConfig(**kw, fault=pol)).fit(X, Y_CLS)
    with pytest.raises(ValueError, match="lam"):
        PEMSVM(SVMConfig(**kw, lam=2.0, fault=pol)).fit(
            X, Y_CLS, resume_from=str(tmp_path))


def test_decay_requires_donor_stats():
    donor = PEMSVM(SVMConfig(algorithm="EM", driver="stream",
                             chunk_rows=64, max_iters=4, min_iters=4)
                   ).fit(X, Y_CLS)           # decay=0 -> no stats kept
    with pytest.raises(ValueError, match="stats"):
        PEMSVM(SVMConfig(algorithm="EM", driver="stream", chunk_rows=64,
                         max_iters=4, min_iters=4, decay=0.5)).fit(
            X, Y_CLS, warm_start=donor)


def test_decay_requires_stream_driver():
    with pytest.raises(AssertionError):
        SVMConfig(driver="scan", decay=0.5)


# --------------------------------------------------- straggler reactions
def test_straggler_record_events(tmp_path):
    """on_straggler='record': a delayed iteration lands in
    FitResult.straggler_events without touching the trajectory."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
              min_iters=10)
    pol = FaultPolicy(on_straggler="record", straggler_threshold=1.5,
                      straggler_warmup=2)
    res = PEMSVM(SVMConfig(**kw, fault=pol)).fit(
        X, Y_CLS, fault_hook=faults.delay_iterations([6], 0.5))
    assert any(e.get("it") == 6 for e in res.straggler_events)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    assert np.array_equal(ref.weights, res.weights)


def test_straggler_raise(tmp_path):
    """on_straggler='raise' hands control to an outer controller — and
    the last committed checkpoint makes the restart lossless."""
    kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
              min_iters=10)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2,
                      on_straggler="raise", straggler_threshold=3.0,
                      straggler_warmup=2)
    # a uniform floor delay dominates sub-ms timing noise, so only the
    # injected spike at iteration 6 crosses 3 x EMA
    floor = faults.delay_iterations(range(1, 11), 0.05)
    with pytest.raises(StragglerError):
        PEMSVM(SVMConfig(**kw, fault=pol)).fit(
            X, Y_CLS, fault_hook=faults.compose_hooks(
                floor, faults.delay_iterations([6], 0.5)))
    res = PEMSVM(SVMConfig(**kw, fault=pol)).fit(
        X, Y_CLS, resume_from=str(tmp_path), fault_hook=floor)
    ref = PEMSVM(SVMConfig(**kw)).fit(X, Y_CLS)
    assert np.array_equal(ref.weights, res.weights)


# -------------------------------------------------- mesh tests (subproc)
def run_with_devices(code: str, n_devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


MESH_HEADER = """
import numpy as np, tempfile
from repro import compat
from repro.core import PEMSVM, SVMConfig
from repro.runtime.policy import FaultPolicy, StragglerError
from repro.runtime import faults
mesh_a = compat.make_mesh((2, 2), ("data", "model"),
                          axis_types=("auto",) * 2)
mesh_b = compat.make_mesh((4,), ("data",), axis_types=("auto",))
rng = np.random.default_rng(0)
N, K = 512, 23
w_true = rng.normal(size=K)
X = rng.normal(size=(N, K)).astype(np.float32)
y = np.where(X @ w_true + 0.3 * rng.normal(size=N) > 0, 1.0, -1.0)
"""


def test_remesh_resume_parity():
    """The elastic headline: kill a fit on a (2,2) mesh with the 2-D
    k-sharded statistic, resume on a flat (4,) mesh. Cross-mesh error
    equals the WHOLE-FIT mesh-reassociation band (EM ~1e-6, MC ~1e-2
    fp32) — resuming adds nothing on top. Resuming onto the SAME mesh
    is bitwise."""
    run_with_devices(MESH_HEADER + """
for algo, band in (("EM", 1e-4), ("MC", 2e-2)):
    kw = dict(algorithm=algo, task="CLS", driver="loop", max_iters=10,
              min_iters=10, burnin=3, eps=1e-2)
    with tempfile.TemporaryDirectory() as d:
        pol = FaultPolicy(ckpt_dir=d, ckpt_every=3, keep_k=10)
        ref_b = PEMSVM(SVMConfig(**kw), mesh=mesh_b,
                       data_axes=("data",)).fit(X, y)
        ref_a = PEMSVM(SVMConfig(**kw, k_shard_axis="model"),
                       mesh=mesh_a, data_axes=("data",)).fit(X, y)
        svm1 = PEMSVM(SVMConfig(**kw, k_shard_axis="model", fault=pol),
                      mesh=mesh_a, data_axes=("data",))
        try:
            svm1.fit(X, y, fault_hook=faults.kill_at_iteration(7))
            raise SystemExit("kill did not fire")
        except faults.SimulatedPreemption:
            pass
        res_b = PEMSVM(SVMConfig(**kw, fault=pol), mesh=mesh_b,
                       data_axes=("data",)).fit(X, y, resume_from=d)
        rel = (np.abs(res_b.weights - ref_b.weights).max()
               / np.abs(ref_b.weights).max())
        assert res_b.resumed_at == 6, res_b.resumed_at
        assert rel < band, (algo, rel)
        res_a = PEMSVM(SVMConfig(**kw, k_shard_axis="model",
                                 fault=pol), mesh=mesh_a,
                       data_axes=("data",)).fit(X, y, resume_from=d,
                                                resume_step=6_000_000)
        assert np.array_equal(res_a.weights, ref_a.weights), algo
print("remesh parity OK")
""")


def test_straggler_drop_and_live_renormalization():
    """on_straggler='drop': a flagged shard is zeroed out of the
    reduction via the live-weighted psum; the renormalized statistic
    targets the full-data sums, so the fit stays close to the
    surviving-rows fit (they differ only in regularizer weighting)."""
    run_with_devices(MESH_HEADER + """
kw = dict(algorithm="EM", task="CLS", driver="loop", max_iters=10,
          min_iters=10, eps=1e-2)
full = PEMSVM(SVMConfig(**kw), mesh=mesh_b, data_axes=("data",)).fit(X, y)

pol = FaultPolicy(on_straggler="drop", straggler_threshold=1.5,
                  straggler_warmup=2)
svm = PEMSVM(SVMConfig(**kw, fault=pol), mesh=mesh_b,
             data_axes=("data",))
svm.report_slow_shard(3)
res = svm.fit(X, y, fault_hook=faults.delay_iterations([6], 0.5))
assert len(res.straggler_events) >= 1
assert np.isfinite(res.weights).all()
assert not np.allclose(res.weights, full.weights)

live = np.array([1, 1, 1, 0], np.float32)
r_live = PEMSVM(SVMConfig(**kw), mesh=mesh_b,
                data_axes=("data",)).fit(X, y, live=live)
shard = N // 4
r_sub = PEMSVM(SVMConfig(**kw)).fit(X[:3 * shard], y[:3 * shard])
rel = (np.abs(r_live.weights - r_sub.weights).max()
       / np.abs(r_sub.weights).max())
assert rel < 5e-2, rel
print("drop/live OK")
""")


# --------------------------------------- in-kernel counter RNG + chains
def test_fused_multichain_midpass_kill_resume_bitwise(tmp_path):
    """The counter-RNG elastic claim: a 3-chain rng='fused' stream fit
    killed INSIDE a pass (mid-chunk snapshot) resumes bitwise — the
    (C, K) chain state, the partial chunk totals (S is (C, K, K) here)
    and the iteration subkey all ride the snapshot, and the in-kernel
    counter re-derives identical noise for the replayed rows."""
    kw = dict(algorithm="MC", task="CLS", driver="stream", chunk_rows=64,
              max_iters=8, min_iters=8, burnin=2, rng="fused", n_chains=3)
    ref = PEMSVM(SVMConfig(**kw)).fit_chunks(_five_chunks, K)

    d = str(tmp_path)
    pol = FaultPolicy(ckpt_dir=d, ckpt_every=100, ckpt_chunks=1)
    cfg = SVMConfig(**kw, fault=pol)
    with pytest.raises(faults.SimulatedPreemption):
        PEMSVM(cfg).fit_chunks(faults.kill_after_chunks(_five_chunks, 18),
                               K)
    payload = resume_mod.load_snapshot(Checkpointer(d))
    assert payload["in_pass"] and payload["chunk_idx"] > 0
    assert payload["state"].shape == (3, K)   # chunk width, chain-major

    res = PEMSVM(cfg).fit_chunks(_five_chunks, K, resume_from=d)
    assert res.resumed_at is not None
    assert np.array_equal(ref.weights, res.weights)
    assert np.array_equal(ref.chain_weights, res.chain_weights)
    assert np.array_equal(ref.chain_std, res.chain_std)


# --------------------------------------------------------- Nystrom path
def test_nystrom_stream_kill_resume_bitwise(tmp_path):
    """The nonlinear path inherits elasticity: landmark selection is
    seed-deterministic and skipped when continuing, so the resumed
    phi-space fit matches the uninterrupted one bitwise."""
    rng = np.random.default_rng(0)
    Xc = rng.normal(size=(300, 6)).astype(np.float32)
    yc = np.where(np.linalg.norm(Xc[:, :2], axis=1) > 1.1, 1.0,
                  -1.0).astype(np.float32)
    kw = dict(formulation="KRN", algorithm="MC", task="CLS",
              driver="stream", chunk_rows=64, max_iters=10, min_iters=10,
              burnin=3, sigma=1.5)
    ref = NystromSVM(SVMConfig(**kw), n_landmarks=32, seed=1)
    rref = ref.fit(Xc, yc)

    d = str(tmp_path)
    pol = FaultPolicy(ckpt_dir=d, ckpt_every=2, ckpt_chunks=2)
    svm1 = NystromSVM(SVMConfig(**kw, fault=pol), n_landmarks=32, seed=1)
    with pytest.raises(faults.SimulatedPreemption):
        svm1.fit(Xc, yc, fault_hook=faults.kill_at_iteration(6))
    svm2 = NystromSVM(SVMConfig(**kw, fault=pol), n_landmarks=32, seed=1)
    res = svm2.fit(Xc, yc, resume_from=d)

    assert np.array_equal(rref.weights, res.weights)
    assert svm2.score(Xc, yc) == ref.score(Xc, yc) > 0.8
