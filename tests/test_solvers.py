"""Solver behaviour: all six paper option axes, convergence, stopping rule,
accuracy parity with the reimplemented baselines."""
import numpy as np
import pytest

from repro.baselines import DCDSVM, PegasosSVM
from repro.core import PEMSVM, SVMConfig, lam_from_C
from repro.data import make_blobs, make_circles, make_year_like


def test_lin_em_cls_converges_within_paper_range(blobs):
    X, y = blobs
    svm = PEMSVM(SVMConfig(lam=1.0, max_iters=100))
    res = svm.fit(X, y)
    # paper Sec 5.13: EM converges in 40-60 iterations
    assert res.converged and res.n_iters <= 80
    assert svm.score(X, y) > 0.95


def test_lin_em_objective_monotone_after_warmup(blobs):
    X, y = blobs
    res = PEMSVM(SVMConfig(lam=1.0, max_iters=50, tol=0.0)).fit(X, y)
    objs = res.objective
    diffs = np.diff(objs[2:])
    assert (diffs <= 1e-3 * abs(objs[0])).mean() > 0.95, \
        "EM objective should be (near-)monotone decreasing"


def test_lin_mc_cls_trains(blobs):
    X, y = blobs
    svm = PEMSVM(SVMConfig(algorithm="MC", lam=1.0, max_iters=60, seed=3))
    res = svm.fit(X, y)
    assert svm.score(X, y) > 0.94
    # posterior averaging must be in effect (Sec 5.13)
    assert not np.allclose(res.weights, res.last_sample)


def test_em_vs_mc_agree(blobs):
    X, y = blobs
    em = PEMSVM(SVMConfig(lam=1.0, max_iters=60))
    mc = PEMSVM(SVMConfig(algorithm="MC", lam=1.0, max_iters=60))
    em.fit(X, y)
    mc.fit(X, y)
    assert abs(em.score(X, y) - mc.score(X, y)) < 0.03


def test_accuracy_parity_with_baselines(blobs):
    """Paper claim: comparable accuracy to state-of-the-art solvers."""
    X, y = blobs
    ours = PEMSVM(SVMConfig(lam=0.01, max_iters=60))
    ours.fit(X, y)
    peg = PegasosSVM(lam=0.01, n_steps=2000).fit(X, y)
    dcd = DCDSVM.from_lam(0.01, n_epochs=8).fit(X, y)
    a0, a1, a2 = ours.score(X, y), peg.score(X, y), dcd.score(X, y)
    assert a0 >= max(a1, a2) - 0.02, (a0, a1, a2)


def test_svr_year_protocol():
    X, y = make_year_like(4000, 30)
    svm = PEMSVM(SVMConfig.from_options(
        "LIN-EM-SVR", lam=lam_from_C(0.01), eps_ins=0.3, max_iters=60))
    svm.fit(X, y)
    rmse = svm.rmse(X, y)
    assert rmse < 0.5, rmse   # paper Table 6 regime (unit-variance targets)
    # score is the higher-is-better convention: negated RMSE for SVR
    assert svm.score(X, y) == -rmse


def test_svr_mc():
    X, y = make_year_like(2000, 20)
    svm = PEMSVM(SVMConfig.from_options("LIN-MC-SVR", lam=0.1, eps_ins=0.1,
                                        max_iters=50))
    svm.fit(X, y)
    assert svm.rmse(X, y) < 0.6


@pytest.mark.parametrize("algo", ["EM", "MC"])
def test_mlt_crammer_singer(algo):
    rng = np.random.default_rng(5)
    N, K, M = 2500, 20, 5
    X = rng.normal(size=(N, K)).astype(np.float32)
    Wt = rng.normal(size=(M, K))
    labels = np.argmax(X @ Wt.T + 0.2 * rng.normal(size=(N, M)),
                       axis=1).astype(np.int32)
    svm = PEMSVM(SVMConfig(algorithm=algo, task="MLT", num_classes=M,
                           lam=1.0, max_iters=40, min_iters=30))
    svm.fit(X, labels)
    assert svm.score(X, labels) > 0.9


def test_krn_rbf_on_circles():
    X, y = make_circles(400)
    svm = PEMSVM(SVMConfig(formulation="KRN", lam=0.1, sigma=0.7,
                           max_iters=40))
    svm.fit(X, y)
    assert svm.score(X, y) > 0.98  # not linearly separable


def test_krn_mc():
    X, y = make_circles(300, seed=2)
    svm = PEMSVM(SVMConfig(formulation="KRN", algorithm="MC", lam=0.1,
                           sigma=0.7, max_iters=50))
    svm.fit(X, y)
    assert svm.score(X, y) > 0.95


def test_linear_sanity_vs_kernel_linear(blobs):
    """KRN with the linear kernel ~ LIN solution (representer theorem)."""
    X, y = blobs
    X, y = X[:400], y[:400]
    lin = PEMSVM(SVMConfig(lam=0.5, max_iters=50))
    lin.fit(X, y)
    k = PEMSVM(SVMConfig(formulation="KRN", kernel="linear", lam=0.5,
                         max_iters=50))
    k.fit(X, y)
    agree = np.mean(lin.predict(X) == k.predict(X))
    # >=: the two formulations land exactly on 0.97 (388/400) on some
    # BLAS/jax builds — a knife-edge strict inequality is not the claim.
    assert agree >= 0.97, agree


def test_stopping_rule_uses_tolN(blobs):
    X, y = blobs
    loose = PEMSVM(SVMConfig(lam=1.0, max_iters=100, tol=1.0)).fit(X, y)
    tight = PEMSVM(SVMConfig(lam=1.0, max_iters=100, tol=1e-6)).fit(X, y)
    assert loose.n_iters <= tight.n_iters


def test_compressed_reduction_single_device_noop(blobs):
    """reduce_dtype only affects on-mesh runs; off-mesh path must accept
    the config and train identically."""
    X, y = blobs
    a = PEMSVM(SVMConfig(lam=1.0, max_iters=30))
    b = PEMSVM(SVMConfig(lam=1.0, max_iters=30, reduce_dtype="bfloat16"))
    ra, rb = a.fit(X, y), b.fit(X, y)
    np.testing.assert_allclose(ra.weights, rb.weights, rtol=1e-5)


def test_config_validation():
    with pytest.raises(AssertionError):
        SVMConfig(formulation="BAD")
    # KRN x SVR is a valid CONFIGURATION (NystromSVM serves it through
    # phi-space); only the exact N x N Gram solver rejects it, at fit.
    cfg = SVMConfig(formulation="KRN", task="SVR")
    with pytest.raises(NotImplementedError):
        PEMSVM(cfg).fit(np.zeros((8, 2), np.float32), np.zeros(8))
    assert SVMConfig.from_options("lin-mc-mlt").options == "LIN-MC-MLT"
    assert lam_from_C(2.0) == 1.0
