"""Unit + property tests for the Polson-Scott augmentation pieces."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import augment


def test_inverse_gaussian_moments():
    """IG(mu, lam): mean = mu, var = mu^3/lam."""
    key = jax.random.PRNGKey(0)
    for mu in [0.3, 1.0, 4.0]:
        x = augment.sample_inverse_gaussian(
            key, jnp.full((200_000,), mu, jnp.float32), lam=1.0)
        assert np.all(np.asarray(x) > 0)
        np.testing.assert_allclose(float(jnp.mean(x)), mu, rtol=0.05)
        np.testing.assert_allclose(float(jnp.var(x)), mu ** 3, rtol=0.2)


def test_gamma_em_matches_paper_eq9():
    res = jnp.asarray([-2.0, -1e-9, 0.0, 0.5, 3.0])
    g = augment.gamma_em(res, eps=1e-6)
    np.testing.assert_allclose(
        np.asarray(g), [2.0, 1e-6, 1e-6, 0.5, 3.0], rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=64),
       st.floats(1e-8, 1e-2))
def test_gamma_em_clamped_positive(vals, eps):
    g = augment.gamma_em(jnp.asarray(vals, jnp.float32), eps=eps)
    assert bool(jnp.all(g >= eps * 0.999))
    assert bool(jnp.all(jnp.isfinite(g)))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-4, 1e3))
def test_gamma_mc_positive_finite(seed, scale):
    key = jax.random.PRNGKey(seed)
    res = scale * jax.random.normal(key, (256,))
    g = augment.gamma_mc(key, res, eps=1e-6)
    assert bool(jnp.all(g >= 1e-6 * 0.999))
    assert bool(jnp.all(jnp.isfinite(g)))


def test_gamma_mc_concentrates_on_em_for_large_residuals():
    """For |residual| >> 0 the IG(1/|r|, 1) draw of gamma^{-1} has mean
    1/|r| and tiny relative variance -> gamma ~= |r| = EM value."""
    key = jax.random.PRNGKey(1)
    res = jnp.full((100_000,), 30.0)
    g = augment.gamma_mc(key, res, eps=1e-6)
    np.testing.assert_allclose(float(jnp.mean(1.0 / g)), 1.0 / 30.0,
                               rtol=0.05)
