"""Data pipeline, sharding rules, runtime monitor, objective properties,
HLO cost analyzer, head pooling."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

import pytest

from repro.core import head, objective
from repro.data import (ShardedBatcher, iter_libsvm, load_libsvm,
                        make_lm_tokens, save_libsvm)
from repro.launch.hlo_cost import analyze
from repro.runtime import StepTimeMonitor
from repro.sharding import ShardingCtx, param_spec


# ------------------------------------------------------------------- data
def test_libsvm_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    X = (rng.random((20, 6)) * (rng.random((20, 6)) > 0.5)).astype(
        np.float32)
    y = rng.choice([-1.0, 1.0], 20)
    p = str(tmp_path / "d.txt")
    save_libsvm(p, X, y)
    X2, y2 = load_libsvm(p, n_features=6)
    np.testing.assert_allclose(X2, X, atol=1e-5)
    np.testing.assert_allclose(y2, y)


def test_libsvm_striped_ranks(tmp_path):
    rng = np.random.default_rng(1)
    X = rng.random((10, 3)).astype(np.float32)
    y = np.ones(10)
    p = str(tmp_path / "d.txt")
    save_libsvm(p, X, y)
    parts = [load_libsvm(p, n_features=3, rank=r, world=2)[0]
             for r in range(2)]
    assert parts[0].shape[0] + parts[1].shape[0] == 10
    np.testing.assert_allclose(np.sort(np.vstack(parts), axis=0),
                               np.sort(X, axis=0), atol=1e-5)


def test_batcher_deterministic_and_seekable():
    stream = make_lm_tokens(50_000, 128, seed=0)
    b1 = ShardedBatcher(stream, 4, 64, seed=1)
    it = iter(b1)
    batches = [next(it) for _ in range(3)]
    b2 = ShardedBatcher(stream, 4, 64, seed=1)
    b2.seek(2)
    t2, l2 = next(iter(b2))
    np.testing.assert_array_equal(np.asarray(batches[2][0]), np.asarray(t2))
    # next-token alignment
    np.testing.assert_array_equal(np.asarray(batches[0][0][:, 1:]),
                                  np.asarray(batches[0][1][:, :-1]))


def test_libsvm_tolerates_comments_and_blanks(tmp_path):
    p = str(tmp_path / "d.txt")
    with open(p, "w") as f:
        f.write("# a header comment\n"
                "\n"
                "1 1:0.5 3:2.0   # trailing comment\n"
                "   \n"
                "-1 2:1.25\n")
    X, y = load_libsvm(p, n_features=3)
    np.testing.assert_allclose(y, [1.0, -1.0])
    np.testing.assert_allclose(X, [[0.5, 0.0, 2.0], [0.0, 1.25, 0.0]])


@pytest.mark.parametrize("bad,msg", [
    ("1 2:0.5 3\n", "malformed 'idx:val' token '3'"),
    ("1 x:0.5\n", "malformed 'idx:val' token 'x:0.5'"),
    ("1 2:abc\n", "malformed 'idx:val' token '2:abc'"),
    ("spam 1:1\n", "label 'spam'"),
    ("1 0:1\n", "feature index 0 out of range"),
])
def test_libsvm_malformed_tokens_raise_clear_errors(tmp_path, bad, msg):
    p = str(tmp_path / "d.txt")
    with open(p, "w") as f:
        f.write("1 1:1.0\n" + bad)
    with pytest.raises(ValueError, match="line 2"):
        load_libsvm(p, n_features=3)
    try:
        load_libsvm(p, n_features=3)
    except ValueError as e:
        assert msg in str(e), e


def test_iter_libsvm_chunks_match_load(tmp_path):
    """Chunked reader == resident loader: concatenated valid rows are
    identical, every block has the fixed shape, tail is masked."""
    rng = np.random.default_rng(5)
    X = (rng.random((23, 4)) * (rng.random((23, 4)) > 0.4)).astype(
        np.float32)
    y = rng.choice([-1.0, 1.0], 23)
    p = str(tmp_path / "d.txt")
    save_libsvm(p, X, y)
    blocks = list(iter_libsvm(p, chunk_rows=7, n_features=4))
    assert len(blocks) == 4
    assert all(b[0].shape == (7, 4) for b in blocks)
    mask = np.concatenate([b[2] for b in blocks])
    assert mask.sum() == 23 and blocks[-1][2].sum() == 2  # 23 = 3*7 + 2
    Xc = np.concatenate([b[0] for b in blocks])[mask > 0]
    yc = np.concatenate([b[1] for b in blocks])[mask > 0]
    Xr, yr = load_libsvm(p, n_features=4)
    np.testing.assert_allclose(Xc, Xr, atol=1e-5)
    np.testing.assert_allclose(yc, yr)
    # padded rows are exact zeros (the stats no-op convention)
    assert np.all(blocks[-1][0][2:] == 0.0) and np.all(
        blocks[-1][1][2:] == 0.0)


def test_iter_libsvm_striped_ranks(tmp_path):
    rng = np.random.default_rng(6)
    X = rng.random((10, 3)).astype(np.float32)
    p = str(tmp_path / "d.txt")
    save_libsvm(p, X, np.ones(10))
    parts = []
    for r in range(2):
        for Xb, yb, mb in iter_libsvm(p, 4, 3, rank=r, world=2):
            parts.append(Xb[mb > 0])
    got = np.sort(np.vstack(parts), axis=0)
    np.testing.assert_allclose(got, np.sort(X, axis=0), atol=1e-5)


def test_batcher_seek_mid_iteration_discards_stale_prefetch():
    """Regression: seek() after the iterator started must not yield
    already-prefetched stale steps — resume must be deterministic."""
    stream = make_lm_tokens(50_000, 128, seed=0)
    ref = ShardedBatcher(stream, 4, 64, seed=1)
    it_ref = iter(ref)
    want = [np.asarray(next(it_ref)[0]) for _ in range(4)]

    b = ShardedBatcher(stream, 4, 64, seed=1, prefetch=3)
    it = iter(b)
    for _ in range(3):
        next(it)               # worker has prefetched steps ~3..5 already
    b.seek(0)                  # checkpoint-restore semantics
    got = np.asarray(next(it)[0])
    np.testing.assert_array_equal(got, want[0])
    # and the sequence continues deterministically from there
    np.testing.assert_array_equal(np.asarray(next(it)[0]), want[1])
    assert b.step == 2


def test_lm_tokens_learnable_structure():
    s = make_lm_tokens(100_000, 512, seed=0)
    assert s.min() >= 0 and s.max() < 512
    # zipf: top-10 tokens cover a large fraction
    _, counts = np.unique(s, return_counts=True)
    assert np.sort(counts)[-10:].sum() > 0.3 * len(s)


# --------------------------------------------------------------- sharding
def test_param_spec_divisibility_filter():
    import jax as _jax
    from repro import compat as _compat
    devs = _jax.devices()
    if len(devs) < 1:
        return
    mesh = _compat.make_mesh((1, 1), ("data", "model"),
                          axis_types=("auto",) * 2)
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                      fsdp_axis="data")
    # divisible: sharded; mesh axes are size 1 so everything divides —
    # check the orientation rules instead
    s = param_spec(ctx, "layers/attn/wq", (4, 64, 64))
    assert s == jax.sharding.PartitionSpec(None, "data", "model")
    s = param_spec(ctx, "layers/attn/wo", (4, 64, 64))
    assert s == jax.sharding.PartitionSpec(None, "model", "data")
    s = param_spec(ctx, "layers/moe/moe_up", (4, 8, 64, 32))
    assert s == jax.sharding.PartitionSpec(None, "model", "data", None)
    s = param_spec(ctx, "embed/table", (100, 64))
    assert s == jax.sharding.PartitionSpec("model", "data")


def test_spec_drops_non_divisible():
    import jax as _jax
    from repro import compat as _compat
    if len(_jax.devices()) != 1:
        return
    mesh = _compat.make_mesh((1,), ("data",),
                          axis_types=("auto",))
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis=None,
                      fsdp_axis="data")
    # everything divides by 1; exercise the API contract
    assert ctx.spec((5, 3), "data", None)[0] == "data"
    assert ctx.axis_size("data") == 1


# ---------------------------------------------------------------- runtime
def test_straggler_monitor_flags_slow_steps():
    m = StepTimeMonitor(warmup_steps=2, threshold=2.0)
    flags = [m.observe(i, t) for i, t in enumerate(
        [1.0, 1.0, 1.0, 1.0, 5.0, 1.0])]
    assert flags == [False, False, False, False, True, False]
    assert m.summary()["straggler_events"] == 1
    # EMA not poisoned by the straggler
    assert m.ema < 1.5


# -------------------------------------------------------------- objective
@settings(max_examples=25, deadline=None)
@given(st.integers(1, 50), st.integers(0, 2 ** 20))
def test_hinge_objective_nonnegative(n, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=n).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], n).astype(np.float32))
    mask = jnp.asarray((rng.random(n) > 0.2).astype(np.float32))
    v = float(objective.hinge_obj_terms(m, y, mask))
    assert v >= 0.0
    # perfect margins -> zero loss
    assert float(objective.hinge_obj_terms(10 * y, y, mask)) == 0.0


def test_cs_objective_zero_iff_unit_margins():
    scores = jnp.asarray([[5.0, 0.0, 0.0], [0.0, 5.0, 0.0]])
    labels = jnp.asarray([0, 1])
    mask = jnp.ones(2)
    assert float(objective.cs_obj_terms(scores, labels, mask)) == 0.0
    bad = jnp.asarray([[0.0, 5.0, 0.0]])
    assert float(objective.cs_obj_terms(bad, jnp.asarray([0]),
                                        jnp.ones(1))) > 0.0


# ---------------------------------------------------------------- hlo_cost
def test_hlo_cost_counts_loop_bodies():
    M = 64

    def scanned(a, b):
        def body(x, _):
            return jnp.tanh(x @ b), None
        y, _ = jax.lax.scan(body, a, None, length=7)
        return y

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
    r = analyze(c.as_text())
    exp = 7 * 2 * M ** 3
    assert 0.9 < r["flops"] / exp < 1.3, r["flops"] / exp


# -------------------------------------------------------------------- head
def test_pooling_helpers():
    h = jnp.arange(24.0).reshape(1, 4, 6)
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    mp = head.mean_pool(h, mask)
    np.testing.assert_allclose(np.asarray(mp)[0], np.asarray(h[0, :2]).mean(0))
    lp = head.last_token_pool(h, jnp.asarray([2]))
    np.testing.assert_allclose(np.asarray(lp)[0], np.asarray(h[0, 1]))
