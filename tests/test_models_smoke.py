"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU, asserting output shapes
and no NaNs, plus prefill->decode consistency against the full-sequence
pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduce_cfg
from repro.configs import SHAPES, applicable, get_config, list_archs
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step

ARCHS = list_archs()
B, S = 2, 32


def _batch(cfg, key, with_labels=False):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model)),
                 "positions": jnp.broadcast_to(
                     jnp.arange(S)[None, None], (3, B, S))}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq,
                                                  cfg.d_model))
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduce_cfg(get_config(arch))
    m = build_model(cfg, q_chunk=16, kv_chunk=16, ssm_chunk=8)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    logits = m.logits_seq(params, _batch(cfg, key))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch):
    cfg = reduce_cfg(get_config(arch))
    m = build_model(cfg, q_chunk=16, kv_chunk=16, ssm_chunk=8)
    key = jax.random.PRNGKey(1)
    state = init_train_state(m, key)
    step = jax.jit(make_train_step(
        m, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30),
        loss_chunk=16))
    batch = _batch(cfg, key, with_labels=True)
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).family != "vlm"])
def test_prefill_decode_matches_full_sequence(arch):
    """Teacher-forcing consistency: prefill(S) then decode token S must
    equal the full-sequence logits at position S."""
    # moe_capacity_factor high: capacity drops are a *batch-level* drop
    # policy and legitimately differ between a 64-token full pass and a
    # 1-token decode; consistency is defined at no-drop capacity.
    cfg = reduce_cfg(get_config(arch), moe_capacity_factor=8.0)
    m = build_model(cfg, q_chunk=16, kv_chunk=16, ssm_chunk=8)
    key = jax.random.PRNGKey(2)
    params = m.init(key)
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch_full = dict(_batch(cfg, key), tokens=toks)
    full = m.logits_seq(params, batch_full).astype(jnp.float32)

    batch_pre = dict(batch_full, tokens=toks[:, :S])
    _, caches = m.prefill(params, batch_pre, cache_len=S + 4)
    lg, _ = m.decode(params, toks[:, S:S + 1], jnp.int32(S), caches)
    got = lg[:, 0].astype(jnp.float32)
    want = full[:, S]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_assigned_full_config_matches_table(arch):
    """The FULL configs must match the assignment table exactly."""
    cfg = get_config(arch)
    table = {
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)
    if arch == "granite-moe-1b-a400m":
        assert (cfg.n_experts, cfg.top_k) == (32, 8)
    if arch == "deepseek-v2-236b":
        assert (cfg.n_experts, cfg.top_k, cfg.kv_lora_rank,
                cfg.n_shared_experts) == (160, 6, 512, 2)
    if arch == "jamba-v0.1-52b":
        assert (cfg.n_experts, cfg.top_k, cfg.attn_every) == (16, 2, 8)
        # 1:7 attention ratio
        n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.n_layers))
        assert n_attn == 4


def test_shape_table_and_applicability():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    # long_500k only for sub-quadratic archs
    for a in ARCHS:
        cfg = get_config(a)
        runs, why = applicable(cfg, SHAPES["long_500k"])
        assert runs == (a in ("jamba-v0.1-52b", "xlstm-350m")), (a, why)
        assert runs or why


def test_mrope_degenerates_to_rope_on_text():
    from repro.models import rotary
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 3, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = rotary.apply_rope(x, pos, 1e4)
    b = rotary.apply_mrope(x, pos3, 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
