"""In-kernel counter-based RNG (``SVMConfig.rng``) and multichain Gibbs.

The contract under test (DESIGN.md §Perf/RNG):

  1. The counter stream itself: ``draw_fused_noise`` (the host oracle)
     is a pure function of (key words, global row, chain id) — chunk
     slices are literal slices, chain planes are independent, and the
     kernel-tile generator (``tile_noise``) emits the SAME bits.
  2. Kernel parity, BITWISE: ``ops.fused_stats`` /
     ``ops.nystrom_fused_stats`` with the (4,) ``seed`` operand equal
     the same call fed the materialized ``noise`` operands — every
     output, every backend, odd masked shapes included.  (This is a
     sharper claim than the host-rng kernel tests can make: the noise
     VALUES are bitwise shared by construction, and everything
     downstream is the same code.)
  3. Operand elimination: under ``seed`` the jaxpr's pallas_call has NO
     (N,)-shaped noise inputs — the (4,) uint32 seed replaces
     ``n_noise`` full-length streams.  Mixed configs (both sources)
     fail loudly, naming the operand and the config knob.
  4. Whole-fit parity: ``rng='fused'`` fits are bitwise equal to
     ``rng='fused_predraw'`` (same driver + backend) for
     {CLS, SVR, MLT} x {linear, Nystrom} x {loop, scan, stream}, on a
     mesh, and at a shifted chain0.  Cross-driver/backend equality is
     NOT claimed — those fits reassociate fp32 sums and were never
     bitwise in host mode either.
  5. Multichain (``n_chains``): C chains ride one X stream; the fit
     exposes per-chain weights, their mean and ddof-1 std, and the
     serving export turns the chain spread into score_with_std.
  6. The rng / n_chains / chain0 fields are SEMANTIC for resume: a
     checkpoint from one counter stream refuses to continue another.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import NystromSVM, PEMSVM, SVMConfig, augment
from repro.core.linear import accumulate_stats
from repro.kernels import epilogues, ops, ref
from repro.kernels import rng as rng_mod
from repro.runtime import faults
from repro.runtime.policy import FaultPolicy
from repro.serving import SVMScorer

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_rng = np.random.default_rng(0)
N, D = 201, 7
X = _rng.normal(size=(N, D)).astype(np.float32)
_w_true = _rng.normal(size=D)
Y_CLS = np.where(X @ _w_true > 0, 1.0, -1.0).astype(np.float32)
Y_SVR = (X @ _w_true).astype(np.float32)
Y_MLT = _rng.integers(0, 3, size=N)


def _run_with_devices(code: str, n_devices: int = 4, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def _fit(task, targets, **kw):
    defaults = dict(algorithm="MC", task=task, max_iters=8, min_iters=8,
                    burnin=2)
    if task == "MLT":
        defaults["num_classes"] = 3
    defaults.update(kw)
    return PEMSVM(SVMConfig(**defaults)).fit(X, targets)


# --------------------------------------------- 1. the counter stream
def test_counter_draws_are_chunk_slice_invariant():
    """Rows [i0, i1) of the full stream are literally the chunk draw at
    row0=i0 — global-row keying makes chunk boundaries invisible,
    bitwise, for both the 2- and 4-stream (SVR) arities."""
    key = jax.random.PRNGKey(7)
    for n_noise in (2, 4):
        full = rng_mod.draw_fused_noise(key, 230, 0, 0, n_noise)
        for i0, i1 in ((0, 64), (64, 193), (193, 230)):
            part = rng_mod.draw_fused_noise(key, i1 - i0, i0, 0, n_noise)
            for f, p in zip(full, part):
                np.testing.assert_array_equal(np.asarray(f)[i0:i1],
                                              np.asarray(p))


def test_counter_chain_planes_independent_and_replayable():
    """Same (key, row, chain) coordinate -> same bits, always; distinct
    chain ids -> distinct streams.  The uniform stays strictly inside
    (0, 1) (the Box-Muller log must never see 0) and the normal stream
    is standard-normal-shaped."""
    key = jax.random.PRNGKey(3)
    draws = [rng_mod.draw_fused_noise(key, 4096, 0, c, 2)
             for c in range(4)]
    again = rng_mod.draw_fused_noise(key, 4096, 0, 2, 2)
    np.testing.assert_array_equal(np.asarray(draws[2][0]),
                                  np.asarray(again[0]))
    for a in range(4):
        for b in range(a + 1, 4):
            assert not np.array_equal(np.asarray(draws[a][0]),
                                      np.asarray(draws[b][0])), (a, b)
    for nu, u in draws:
        u = np.asarray(u)
        assert (u > 0).all() and (u < 1).all()
        nu = np.asarray(nu)
        assert abs(nu.mean()) < 0.1 and abs(nu.std() - 1.0) < 0.05


def test_tile_noise_matches_host_oracle_per_chain():
    """The kernel-body generator (seed words + tile row offset +
    broadcasted iota) emits, per chain column, exactly the host
    oracle's stream for that chain id — the bitwise bridge every
    kernel-parity test below stands on."""
    key = jax.random.PRNGKey(11)
    row0, chain0, bn, C = 37, 5, 64, 3
    seed = np.asarray(rng_mod.pack_seed(key, row0, chain0))
    for n_noise in (2, 4):
        tile = rng_mod.tile_noise(seed, 128, (bn, C), n_noise)
        for c in range(C):
            want = rng_mod.draw_fused_noise(key, bn, row0 + 128,
                                            chain0 + c, n_noise)
            for t, w in zip(tile, want):
                np.testing.assert_array_equal(np.asarray(t)[:, c],
                                              np.asarray(w))


# ------------------------------------- 2. kernel parity, seed vs operand
@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("epilogue", ["mc_hinge", "mc_svr"])
@pytest.mark.parametrize("n,k,n_valid", [(100, 7, 100), (128, 24, 77),
                                         (9, 33, 9)])
def test_seed_equals_noise_operands_bitwise(backend, epilogue, n, k,
                                            n_valid):
    """ops.fused_stats with the (4,) counter seed == the same call fed
    the materialized noise operands, bitwise on EVERY output — margins,
    draws, b, Sigma — for both MC epilogues, both backends, odd masked
    shapes, and a nonzero row0/chain0."""
    rng = np.random.default_rng(n * k)
    Xb = np.zeros((n, k), np.float32)
    y = np.zeros((n,), np.float32)
    Xb[:n_valid] = rng.normal(size=(n_valid, k)).astype(np.float32)
    y[:n_valid] = rng.choice([-1.0, 1.0], n_valid)
    w = rng.normal(size=k).astype(np.float32)
    key, row0, chain0 = jax.random.PRNGKey(n + k), 37, 2
    n_noise = epilogues.noise_arity(epilogue)
    noise = rng_mod.draw_fused_noise(key, n, row0, chain0, n_noise)
    seed = rng_mod.pack_seed(key, row0, chain0)
    kw = dict(epilogue=epilogue, eps=1e-6, eps_ins=0.2, backend=backend,
              block_n=64)
    args = (jnp.asarray(Xb), jnp.asarray(y), jnp.asarray(y),
            jnp.asarray(w), None)
    got = ops.fused_stats(*args, None, seed=seed, **kw)
    want = ops.fused_stats(*args, noise, **kw)
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


@pytest.mark.parametrize("backend", ["ref", "interpret"])
@pytest.mark.parametrize("epilogue", ["mc_hinge", "mc_svr"])
def test_nystrom_seed_equals_noise_operands_bitwise(backend, epilogue):
    """Phi-space flavor: the fused Nystrom kernel under the counter
    seed == the operand path, bitwise, masked rows and phi bias on."""
    rng = np.random.default_rng(31)
    n, d, m = 100, 7, 37
    Xb = rng.normal(size=(n, d)).astype(np.float32)
    L = Xb[rng.choice(n, m, replace=False)]
    proj = (0.2 * rng.normal(size=(m, m))).astype(np.float32)
    mask = (rng.uniform(size=n) > 0.25).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    w = rng.normal(size=m + 1).astype(np.float32)
    key, row0 = jax.random.PRNGKey(5), 19
    n_noise = epilogues.noise_arity(epilogue)
    noise = rng_mod.draw_fused_noise(key, n, row0, 0, n_noise)
    seed = rng_mod.pack_seed(key, row0, 0)
    kw = dict(sigma=1.3, kind="rbf", add_bias=True, epilogue=epilogue,
              eps=1e-6, eps_ins=0.1, backend=backend, block_n=32)
    args = (jnp.asarray(Xb), jnp.asarray(L), jnp.asarray(proj),
            jnp.asarray(y), jnp.asarray(y), jnp.asarray(w),
            jnp.asarray(mask))
    got = ops.nystrom_fused_stats(*args, None, seed=seed, **kw)
    want = ops.nystrom_fused_stats(*args, noise, **kw)
    for g, w_ in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w_))


# ------------------------------- 3. operand elimination + loud failures
def _pallas_calls(jaxpr):
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):
                out.extend(_pallas_calls(v.jaxpr))
    return out


def test_seed_mode_eliminates_row_noise_operands():
    """Jaxpr walk: under rng='fused' the pallas_call takes NO (n,)
    noise inputs — its operand list is exactly the predraw list minus
    the n_noise full-length streams, plus one (4,) uint32 seed."""
    n, k = 128, 16
    Xb = jnp.asarray(_rng.normal(size=(n, k)).astype(np.float32))
    y = jnp.asarray(_rng.choice([-1.0, 1.0], n).astype(np.float32))
    w = jnp.zeros((k,), jnp.float32)
    key = jax.random.PRNGKey(0)

    def run(rng):
        return lambda X_, y_, w_: accumulate_stats(
            X_, y_, y_, w_, mode="MC", key=key, eps=1e-6,
            backend="interpret", row0=0, rng=rng)

    seeded = _pallas_calls(jax.make_jaxpr(run("fused"))(Xb, y, w).jaxpr)
    predrawn = _pallas_calls(
        jax.make_jaxpr(run("fused_predraw"))(Xb, y, w).jaxpr)
    assert len(seeded) == 1 and len(predrawn) == 1
    s_in = [v.aval for v in seeded[0].invars]
    p_in = [v.aval for v in predrawn[0].invars]
    # the kernels carry row streams as (n, 1) columns
    n_row = lambda avals: sum(a.shape in ((n,), (n, 1)) for a in avals)
    assert n_row(p_in) - n_row(s_in) == epilogues.noise_arity("mc_hinge")
    assert sum(a.shape == (4,) and a.dtype == jnp.uint32
               for a in s_in) == 1
    assert not any(a.shape == (4,) for a in p_in)


def test_mixed_noise_and_seed_rejected_naming_both_knobs():
    """Exactly one noise source: passing pre-drawn operands AND the
    counter seed fails loudly, pointing at both the operand and the
    SVMConfig.rng knob."""
    n, k = 32, 8
    Xb = jnp.zeros((n, k), jnp.float32)
    y = jnp.zeros((n,), jnp.float32)
    w = jnp.zeros((k,), jnp.float32)
    key = jax.random.PRNGKey(0)
    noise = rng_mod.draw_fused_noise(key, n, 0, 0, 2)
    seed = rng_mod.pack_seed(key)
    with pytest.raises(ValueError, match=r"noise=.*rng='host'"):
        ops.fused_stats(Xb, y, y, w, None, noise, seed=seed,
                        epilogue="mc_hinge", eps=1e-6, backend="ref")


def test_config_rejects_unreachable_rng_combinations():
    with pytest.raises(AssertionError, match="MC"):
        SVMConfig(algorithm="EM", rng="fused")
    with pytest.raises(AssertionError, match="rng='fused'"):
        SVMConfig(algorithm="MC", rng="host", n_chains=2)
    with pytest.raises(AssertionError, match="CLS/SVR"):
        SVMConfig(algorithm="MC", task="MLT", num_classes=3, rng="fused",
                  n_chains=2)
    # exact-Gram KRN has no counter plumbing; NystromSVM (which builds
    # a LIN delegate) is the supported kernel route
    with pytest.raises(ValueError, match="NystromSVM"):
        PEMSVM(SVMConfig(formulation="KRN", algorithm="MC", rng="fused"))


# ----------------------------------------------- 4. whole-fit parity
@pytest.mark.parametrize("driver", ["loop", "scan", "stream"])
@pytest.mark.parametrize("task", ["CLS", "SVR", "MLT"])
def test_fit_fused_equals_predraw_bitwise(task, driver):
    """The headline gate: rng='fused' reproduces the materialized-
    noise oracle fit bit for bit — every task, every driver (same
    driver on both sides; drivers reassociate sums and are not
    bitwise-comparable to EACH OTHER, in any rng mode)."""
    tgt = {"CLS": Y_CLS, "SVR": Y_SVR, "MLT": Y_MLT}[task]
    kw = dict(driver=driver)
    if driver == "stream":
        kw["chunk_rows"] = 64
    a = _fit(task, tgt, rng="fused", **kw)
    b = _fit(task, tgt, rng="fused_predraw", **kw)
    h = _fit(task, tgt, rng="host", **kw)
    assert np.array_equal(a.weights, b.weights)
    assert np.array_equal(a.objective, b.objective)
    # distinct source from the host tree (counter bits != fold_in tree)
    assert not np.array_equal(a.weights, h.weights)


def test_fit_fused_equals_predraw_at_shifted_chain0():
    """chain0 relocates the whole fit to another counter plane: still
    bitwise vs the oracle there, and a different chain than plane 0."""
    a0 = _fit("CLS", Y_CLS, rng="fused")
    a = _fit("CLS", Y_CLS, rng="fused", chain0=3)
    b = _fit("CLS", Y_CLS, rng="fused_predraw", chain0=3)
    assert np.array_equal(a.weights, b.weights)
    assert not np.array_equal(a.weights, a0.weights)


@pytest.mark.parametrize("driver", ["loop", "stream"])
@pytest.mark.parametrize("task", ["CLS", "SVR", "MLT"])
def test_nystrom_fit_fused_equals_predraw_bitwise(task, driver):
    """Same gate through the Nystrom phi route (featurize-in-kernel):
    the user-facing KRN config carries rng through to the LIN
    delegate."""
    tgt = {"CLS": Y_CLS, "SVR": Y_SVR, "MLT": Y_MLT}[task]
    kw = dict(formulation="KRN", algorithm="MC", task=task, sigma=1.2,
              max_iters=6, min_iters=6, burnin=2, driver=driver)
    if task == "MLT":
        kw["num_classes"] = 3
    if driver == "stream":
        kw["chunk_rows"] = 64
    fits = {}
    for rng in ("fused", "fused_predraw"):
        ny = NystromSVM(SVMConfig(**kw, rng=rng), n_landmarks=16, seed=1)
        fits[rng] = ny.fit(X, tgt)
    assert np.array_equal(fits["fused"].weights,
                          fits["fused_predraw"].weights)


def test_fused_fit_is_mesh_layout_invariant():
    """A (2, 2) and a (1, 4) mesh run the SAME counter stream: fused
    == predraw bitwise on each mesh, and the two meshes' draws agree
    (gamma_mean to psum-reassociation tolerance at w=0, where margins
    are exactly zero on every layout)."""
    _run_with_devices("""
import numpy as np
from repro import compat
from repro.core import PEMSVM, SVMConfig
mesh_a = compat.make_mesh((2, 2), ("data", "model"),
                          axis_types=("auto",) * 2)
mesh_b = compat.make_mesh((1, 4), ("model", "data"),
                          axis_types=("auto",) * 2)
rng = np.random.default_rng(0)
N, K = 512, 16
Xm = rng.normal(size=(N, K)).astype(np.float32)
w_true = rng.normal(size=K)
ym = np.where(Xm @ w_true > 0, 1.0, -1.0)
for task, tgt in (("CLS", ym), ("SVR", (Xm @ w_true).astype(np.float32))):
    kw = dict(algorithm="MC", task=task, burnin=0, max_iters=1,
              min_iters=1, eps_ins=0.3)
    outs = {}
    for name, mesh, axes in (("a", mesh_a, ("data",)),
                             ("b", mesh_b, ("data",))):
        f = PEMSVM(SVMConfig(**kw, rng="fused"), mesh=mesh,
                   data_axes=axes).fit(Xm, tgt)
        p = PEMSVM(SVMConfig(**kw, rng="fused_predraw"), mesh=mesh,
                   data_axes=axes).fit(Xm, tgt)
        assert np.array_equal(f.weights, p.weights), (task, name)
        outs[name] = f
    r1 = PEMSVM(SVMConfig(**kw, rng="fused")).fit(Xm, tgt)
    for name, r in outs.items():
        np.testing.assert_allclose(r.aux_history["gamma_mean"][0],
                                   r1.aux_history["gamma_mean"][0],
                                   rtol=1e-5, err_msg=(task, name))
print("fused mesh invariance OK")
""")


# ------------------------------------------------------- 5. multichain
@pytest.mark.parametrize("task,tgt", [("CLS", Y_CLS), ("SVR", Y_SVR)])
def test_multichain_fit_exposes_chain_ensemble(task, tgt):
    """n_chains=C: FitResult carries the (C, K) per-chain weights,
    weights == their float64 mean, chain_std == their ddof-1 std, and
    the chains are distinct (independent counter planes)."""
    C = 3
    res = _fit(task, tgt, rng="fused", n_chains=C)
    K = res.weights.shape[0]
    assert res.chain_weights.shape == (C, K)
    assert res.chain_std.shape == (K,)
    cw = res.chain_weights.astype(np.float64)
    np.testing.assert_array_equal(
        res.weights, cw.mean(axis=0).astype(np.float32))
    np.testing.assert_array_equal(
        res.chain_std, cw.std(axis=0, ddof=1).astype(np.float32))
    for a in range(C):
        for b in range(a + 1, C):
            assert not np.array_equal(res.chain_weights[a],
                                      res.chain_weights[b])
    # single-chain fits keep the legacy surface
    single = _fit(task, tgt, rng="fused")
    assert single.chain_weights is None and single.chain_std is None


@pytest.mark.parametrize("driver", ["scan", "stream"])
def test_multichain_drivers_agree(driver):
    """The multichain state threads every driver; loop vs {scan,
    stream} is the usual whole-fit reassociation band, and fused ==
    predraw stays OUT of reach here on purpose (fused_predraw is the
    single-chain operand path — only the in-kernel counter can
    address C planes)."""
    kw = dict(rng="fused", n_chains=3)
    if driver == "stream":
        kw["chunk_rows"] = 64
    a = _fit("CLS", Y_CLS, driver="loop", **kw)
    b = _fit("CLS", Y_CLS, driver=driver, **kw)
    assert a.chain_weights.shape == b.chain_weights.shape == (3, D + 1)
    # Not bitwise on purpose: the (N, K) @ (K, C) margin matmul tiles
    # differently inside lax.scan / per-chunk jits than in the loop
    # step's XLA program (same reassociation channel as stream's
    # chunk-summed S), and the chain amplifies the lsb over iterations.
    rel = (np.abs(a.chain_weights - b.chain_weights).max()
           / np.abs(a.chain_weights).max())
    assert rel < 5e-2, rel


def test_multichain_serving_scores_with_chain_spread():
    """export_servable of a multichain fit serves the chain ensemble:
    margins from the mean weights, score_with_std's band == the ddof-1
    std of the per-chain margins."""
    C = 4
    svm = PEMSVM(SVMConfig(algorithm="MC", max_iters=8, min_iters=8,
                           burnin=2, rng="fused", n_chains=C))
    res = svm.fit(X, Y_CLS)
    sc = SVMScorer(svm.export_servable())
    margin, std = sc.score_with_std(X[:64])
    Xb = np.concatenate([X[:64], np.ones((64, 1), np.float32)], axis=1)
    chain_scores = (Xb.astype(np.float64)
                    @ res.chain_weights.astype(np.float64).T)
    np.testing.assert_allclose(margin, chain_scores.mean(axis=1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(std, chain_scores.std(axis=1, ddof=1),
                               rtol=1e-3, atol=1e-5)
    assert np.all(std > 0)


# ------------------------------------------- 6. resume semantics
def test_resume_rejects_other_counter_stream(tmp_path):
    """rng / n_chains / chain0 are inside the config fingerprint: a
    checkpoint is a position in ONE counter stream, and resuming it
    under another stream fails naming the mismatched field."""
    kw = dict(algorithm="MC", task="CLS", driver="loop", max_iters=6,
              min_iters=6, burnin=2, rng="fused", n_chains=2)
    pol = FaultPolicy(ckpt_dir=str(tmp_path), ckpt_every=2)
    PEMSVM(SVMConfig(**kw, fault=pol)).fit(X, Y_CLS)
    for field, other in (("rng", dict(rng="fused_predraw", n_chains=1)),
                         ("n_chains", dict(n_chains=3)),
                         ("chain0", dict(chain0=7))):
        with pytest.raises(ValueError, match=field):
            PEMSVM(SVMConfig(**{**kw, **other}, fault=pol)).fit(
                X, Y_CLS, resume_from=str(tmp_path))
